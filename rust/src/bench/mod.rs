//! Bench harness utilities (criterion is unavailable in this vendored
//! environment; the `[[bench]]` targets use `harness = false` and this
//! module for timing, table rendering, and result persistence).
//!
//! The structured PerfLab harness — the named-benchmark registry, the
//! `BENCH_<suite>.json` schema, and the baseline-diff regression gate
//! behind `gauntlet bench` — lives in [`suite`]; the paper-figure
//! reproductions the `rust/benches/` binaries wrap live in [`figures`].

pub mod figures;
pub mod suite;

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use crate::minjson::{self, Value};
use crate::util::{mean, percentile, std_dev};

/// Timing summary of repeated measurements.
#[derive(Clone, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn per_iter_human(&self) -> String {
        human_duration(self.mean_s)
    }
}

pub fn human_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
// bench is an edge module (detlint classification): measurement code is
// *about* the clock, so the disallowed-methods tier is opted out here.
#[allow(clippy::disallowed_methods)]
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Collapse raw per-iteration samples into a [`Timing`]. Degenerate inputs
/// are handled deterministically instead of propagated (the same policy
/// `coordinator::scoring::normalize_scores` applies to scores): an empty
/// sample set yields all-zero statistics rather than the ±inf the naive
/// min/max folds produce at `iters == 0`, and non-finite samples are
/// quarantined — excluded from every statistic — so one NaN cannot poison
/// a whole suite result.
pub fn summarize(samples: &[f64]) -> Timing {
    let clean: Vec<f64> = samples.iter().copied().filter(|s| s.is_finite()).collect();
    if clean.is_empty() {
        return Timing { iters: 0, mean_s: 0.0, std_s: 0.0, p50_s: 0.0, min_s: 0.0, max_s: 0.0 };
    }
    Timing {
        iters: clean.len(),
        mean_s: mean(&clean),
        std_s: std_dev(&clean),
        p50_s: percentile(&clean, 50.0),
        min_s: clean.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: clean.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Wall-clock a single closure.
#[allow(clippy::disallowed_methods)]
pub fn elapsed<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Speedup of `mean_s` relative to `baseline_s` (e.g. a sequential run),
/// guarded against zero timings.
pub fn speedup(baseline_s: f64, mean_s: f64) -> f64 {
    baseline_s / mean_s.max(1e-12)
}

/// Render a speedup column ("1.00x" for the baseline itself).
pub fn format_speedup(baseline_s: f64, mean_s: f64) -> String {
    format!("{:.2}x", speedup(baseline_s, mean_s))
}

/// Plain-text table with aligned columns (the bench targets print the
/// paper's tables/series in this shape).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, "{c:<w$}  ");
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Persist a bench result JSON under `bench_results/` for later plotting.
pub fn save_json(name: &str, value: &Value) {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, value.write()).is_ok() {
        println!("[saved {}]", path.display());
    }
}

/// Series (x, y) -> JSON for save_json.
pub fn series_json(pairs: &[(f64, f64)]) -> Value {
    Value::Arr(
        pairs
            .iter()
            .map(|(x, y)| minjson::obj(vec![("x", minjson::num(*x)), ("y", minjson::num(*y))]))
            .collect(),
    )
}

/// Render a crude ASCII sparkline of a series (losses over rounds) so bench
/// output shows the curve shape directly in the terminal.
pub fn sparkline(ys: &[f64], width: usize) -> String {
    if ys.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = ys.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let step = (ys.len() as f64 / width.max(1) as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < ys.len() && out.chars().count() < width {
        let y = ys[i as usize];
        let b = (((y - lo) / span) * 7.0).round() as usize;
        out.push(BARS[b.min(7)]);
        i += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_reports_sane_stats() {
        let t = time_it(1, 5, || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.002, "mean {}", t.mean_s);
        assert!(t.min_s <= t.p50_s && t.p50_s <= t.max_s);
    }

    #[test]
    fn summarize_guards_empty_samples() {
        // iters == 0 used to fold min/max over ±inf; all stats must be
        // finite zeros instead.
        let t = time_it(0, 0, || {});
        assert_eq!(t.iters, 0);
        assert_eq!((t.mean_s, t.p50_s, t.min_s, t.max_s), (0.0, 0.0, 0.0, 0.0));
        assert!(t.std_s == 0.0);
    }

    #[test]
    fn summarize_quarantines_non_finite_samples() {
        let t = summarize(&[1.0, f64::NAN, 3.0, f64::INFINITY, f64::NEG_INFINITY]);
        assert_eq!(t.iters, 2, "only the finite samples count");
        assert!((t.mean_s - 2.0).abs() < 1e-12);
        assert_eq!(t.min_s, 1.0);
        assert_eq!(t.max_s, 3.0);
        let all_bad = summarize(&[f64::NAN, f64::INFINITY]);
        assert_eq!(all_bad.iters, 0);
        assert_eq!(all_bad.min_s, 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines.len(), 5); // title, header, rule, 2 rows
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn human_duration_scales() {
        assert_eq!(human_duration(2.0), "2.000 s");
        assert_eq!(human_duration(0.0021), "2.100 ms");
        assert!(human_duration(3e-6).contains("µs"));
    }

    #[test]
    fn speedup_is_relative_to_baseline() {
        assert!((speedup(2.0, 0.5) - 4.0).abs() < 1e-9);
        assert_eq!(format_speedup(1.0, 1.0), "1.00x");
        assert_eq!(format_speedup(3.0, 1.5), "2.00x");
        assert!(speedup(1.0, 0.0).is_finite(), "zero timing guarded");
    }

    #[test]
    fn sparkline_is_bounded_and_monotone_shape() {
        let ys: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = sparkline(&ys, 20);
        assert!(s.chars().count() <= 20);
        let first = s.chars().next().unwrap();
        let last = s.chars().last().unwrap();
        assert_ne!(first, last, "rising series should change bars");
        assert_eq!(sparkline(&[], 10), "");
    }
}
