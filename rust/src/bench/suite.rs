//! PerfLab: the unified benchmark suite behind `gauntlet bench`.
//!
//! The paper's deployment bottleneck is validator-side evaluation
//! throughput — every validator scores every peer's pseudo-gradient every
//! round — so this module turns the repository's scattered bench binaries
//! into one harness with three properties the ad-hoc tables lacked:
//!
//! 1. **A registry of named benchmarks** ([`registry`]): sparse DeMo
//!    aggregation, wire encode/decode, OpenSkill updates, a Yuma epoch at
//!    deployed scale (64 validators x 256 peers), the SimExec lane kernels
//!    (`grad_into`, `loss_delta` single vs batched at 8/32 candidates vs
//!    the scalar reference, `eval_peer_batch`), the fast-eval fan-out, and
//!    the full round pipeline swept over worker-thread counts. Names
//!    are stable identifiers — they are what baseline diffs key on.
//! 2. **A machine-readable schema** ([`SuiteResult`]): `BENCH_<suite>.json`
//!    carries a run fingerprint (git commit, thread budget, OS) plus
//!    per-bench mean/p50/min/std and workload throughput, and round-trips
//!    losslessly through `minjson` ([`SuiteResult::from_json`]).
//! 3. **A baseline-diff mode** ([`compare`]): ratios of current vs
//!    baseline mean per bench, with anything slower than `fail_over`
//!    reported as a regression — the CI `perf-smoke` job exits non-zero
//!    on it (`gauntlet bench --suite hotpath --compare
//!    baseline/BENCH_hotpath.json --fail-over 1.25`).
//!
//! `--quick` shrinks iteration counts (and the round-pipeline workload)
//! for PR-gate latency but still runs **every** registered bench, so quick
//! results carry the same bench names as full results. Quick and full
//! timings are *not* comparable, which is why [`SuiteResult`] records the
//! mode and the CLI refuses to `--compare` across modes.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::{human_duration, time_it, Table, Timing};
#[allow(deprecated)] // yuma_epoch_64x256 deliberately pins the dense shim
use crate::chain::yuma::yuma_consensus;
use crate::chain::yuma::YumaParams;
use crate::chain::{Chain, Uid};
use crate::coordinator::engine::GauntletBuilder;
use crate::coordinator::fast_eval::{fast_evaluate_all, RoundChecks};
use crate::coordinator::run::RunConfig;
use crate::data::Corpus;
use crate::demo::aggregate::{aggregate_into, AggregateOpts};
use crate::demo::wire::Submission;
use crate::demo::SparseGrad;
use crate::minjson::{self, field, fnum, read_f64, Value};
use crate::openskill::{PlackettLuce, Rating};
use crate::peers::Behavior;
use crate::runtime::{EvalPeerCase, ExecBackend, SimExec, SimSpec, WorkerPool};
use crate::storage::{ObjectStore, ProviderModel, ReadKey};
use crate::util::Rng;

/// Version stamp of the `BENCH_<suite>.json` schema; bumped on any
/// incompatible change so stale baselines fail loudly instead of diffing
/// garbage.
pub const SCHEMA_VERSION: u64 = 1;

// ---------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------

/// Knobs shared by every benchmark in a suite run.
pub struct BenchCtx {
    /// Shrink iteration counts for PR-gate latency (`--quick`). Every
    /// registered bench still runs at least once.
    pub quick: bool,
}

impl BenchCtx {
    /// Scale a full-mode iteration count down in quick mode (>= 2, so
    /// mean/p50 stay meaningful).
    pub fn iters(&self, full: usize) -> usize {
        if self.quick {
            (full / 10).max(2)
        } else {
            full
        }
    }

    /// Warmup calls before timing starts.
    pub fn warmup(&self, full: usize) -> usize {
        if self.quick {
            1
        } else {
            full
        }
    }
}

/// What one benchmark measured.
pub struct BenchOutcome {
    pub timing: Timing,
    /// Workload-specific rate, e.g. `(812.4, "Mcoeff/s")`.
    pub throughput: Option<(f64, &'static str)>,
}

/// One registered benchmark. `run` returns `Ok(None)` when the bench has
/// nothing to measure in this environment (e.g. compiled artifacts are
/// missing) — it is reported as skipped, not failed.
pub struct Benchmark {
    pub name: &'static str,
    #[allow(clippy::type_complexity)]
    pub run: Box<dyn Fn(&BenchCtx) -> Result<Option<BenchOutcome>>>,
}

/// A named set of benchmarks (`gauntlet bench --suite <name>`).
pub struct SuiteSpec {
    pub name: &'static str,
    pub description: &'static str,
    pub benches: Vec<Benchmark>,
}

fn bench(
    name: &'static str,
    run: impl Fn(&BenchCtx) -> Result<Option<BenchOutcome>> + 'static,
) -> Benchmark {
    Benchmark { name, run: Box::new(run) }
}

/// Every registered suite. Bench *names* are the stable contract baseline
/// diffs key on; adding a bench requires a baseline refresh before the CI
/// gate covers it (see `baseline/README.md`).
pub fn registry() -> Vec<SuiteSpec> {
    vec![
        SuiteSpec {
            name: "hotpath",
            description: "per-round critical path: aggregation, wire codec, \
                          ratings, Yuma, pool dispatch, SimExec lane kernels, \
                          fast-eval fan-out, full-round thread sweep",
            benches: vec![
                bench("aggregate_g4_c1312", |c| bench_aggregate(c, 4, 1312, 167_936)),
                bench("aggregate_g15_c1312", |c| bench_aggregate(c, 15, 1312, 167_936)),
                bench("aggregate_g15_c57952", |c| bench_aggregate(c, 15, 57_952, 7_372_800)),
                bench("wire_encode_c1312", |c| bench_wire(c, 1312, true)),
                bench("wire_decode_c1312", |c| bench_wire(c, 1312, false)),
                bench("wire_encode_c57952", |c| bench_wire(c, 57_952, true)),
                bench("wire_decode_c57952", |c| bench_wire(c, 57_952, false)),
                bench("openskill_match_16", bench_openskill),
                bench("yuma_epoch_64x256", bench_yuma),
                bench("chain_epoch_10k", |c| bench_chain_epoch(c, 10_000, 1_000, 16)),
                bench("chain_epoch_100k", |c| bench_chain_epoch(c, 100_000, 1_000, 16)),
                bench("chain_epoch_1m_sparse", |c| bench_chain_epoch(c, 1_000_000, 1_000, 16)),
                bench("corpus_shard", bench_corpus),
                bench("pool_dispatch_j16_t4", bench_pool_dispatch),
                bench("kernel_grad_into_mid", bench_kernel_grad),
                bench("kernel_loss_delta_scalar_ref_mid", bench_kernel_loss_delta_scalar),
                bench("kernel_loss_delta_mid", |c| bench_kernel_loss_delta(c, 1)),
                bench("kernel_loss_delta_batch8_mid", |c| bench_kernel_loss_delta(c, 8)),
                bench("kernel_loss_delta_batch32_mid", |c| bench_kernel_loss_delta(c, 32)),
                bench("kernel_eval_peer_batch8_mid", |c| bench_kernel_eval_peer(c, 8)),
                bench("fasteval_32p_seq", |c| bench_fasteval(c, 1)),
                bench("fasteval_32p_fan4", |c| bench_fasteval(c, 4)),
                bench("round_pipeline_t1", |c| bench_round_pipeline(c, 1, 0.0)),
                bench("round_pipeline_t2", |c| bench_round_pipeline(c, 2, 0.0)),
                bench("round_pipeline_t4", |c| bench_round_pipeline(c, 4, 0.0)),
                bench("round_pipeline_t8", |c| bench_round_pipeline(c, 8, 0.0)),
                bench("round_pipeline_chaos_t4", |c| bench_round_pipeline(c, 4, 0.1)),
            ],
        },
    ]
}

/// Look a suite up by name.
pub fn find_suite(name: &str) -> Option<SuiteSpec> {
    registry().into_iter().find(|s| s.name == name)
}

// ---------------------------------------------------------------------
// runner
// ---------------------------------------------------------------------

/// Run every benchmark in `spec`, print the human table, and return the
/// machine-readable result.
pub fn run_suite(spec: &SuiteSpec, ctx: &BenchCtx) -> Result<SuiteResult> {
    let title = if ctx.quick {
        format!("{} suite (quick)", spec.name)
    } else {
        format!("{} suite", spec.name)
    };
    let mut table = Table::new(&title, &["bench", "mean", "p50", "min", "throughput"]);
    let mut benches = Vec::new();
    for b in &spec.benches {
        let outcome = (b.run)(ctx).with_context(|| format!("bench {:?}", b.name))?;
        let Some(out) = outcome else {
            println!("[skipped {}: nothing to measure in this environment]", b.name);
            continue;
        };
        table.row(&[
            b.name.to_string(),
            human_duration(out.timing.mean_s),
            human_duration(out.timing.p50_s),
            human_duration(out.timing.min_s),
            out.throughput
                .map(|(v, unit)| format!("{v:.1} {unit}"))
                .unwrap_or_default(),
        ]);
        benches.push(BenchRecord {
            name: b.name.to_string(),
            iters: out.timing.iters,
            mean_s: out.timing.mean_s,
            p50_s: out.timing.p50_s,
            min_s: out.timing.min_s,
            std_s: out.timing.std_s,
            throughput: out.throughput.map(|(v, _)| v),
            throughput_unit: out.throughput.map(|(_, u)| u.to_string()),
        });
    }
    table.print();
    Ok(SuiteResult {
        schema_version: SCHEMA_VERSION,
        suite: spec.name.to_string(),
        quick: ctx.quick,
        fingerprint: RunFingerprint {
            git_commit: git_commit(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            os: std::env::consts::OS.to_string(),
        },
        benches,
    })
}

/// Write a result to the conventional location
/// (`rust/bench_results/BENCH_<suite>.json`) for the bench binaries; the
/// CLI writes wherever `--out` points instead.
pub fn save_default(result: &SuiteResult) -> Result<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results");
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
    let path = dir.join(format!("BENCH_{}.json", result.suite));
    std::fs::write(&path, result.to_json().write())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("[saved {}]", path.display());
    Ok(path)
}

/// Best-effort current git commit for the result fingerprint, read straight
/// from `.git` (no subprocess): resolves `HEAD` through loose and packed
/// refs; "unknown" outside a checkout.
pub fn git_commit() -> String {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut roots = Vec::new();
    if let Some(parent) = manifest.parent() {
        roots.push(parent.to_path_buf());
    }
    roots.push(manifest);
    for root in roots {
        let git = root.join(".git");
        let Ok(head) = std::fs::read_to_string(git.join("HEAD")) else { continue };
        let head = head.trim();
        let Some(r) = head.strip_prefix("ref: ") else {
            if !head.is_empty() {
                return head.to_string(); // detached HEAD: the sha itself
            }
            continue;
        };
        if let Ok(sha) = std::fs::read_to_string(git.join(r)) {
            let sha = sha.trim();
            if !sha.is_empty() {
                return sha.to_string();
            }
        }
        if let Ok(packed) = std::fs::read_to_string(git.join("packed-refs")) {
            for line in packed.lines() {
                if let Some(sha) = line.strip_suffix(r) {
                    return sha.trim().to_string();
                }
            }
        }
    }
    "unknown".to_string()
}

// ---------------------------------------------------------------------
// schema
// ---------------------------------------------------------------------

/// One bench's summary inside a [`SuiteResult`].
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub std_s: f64,
    /// Workload-specific rate, if the bench reports one.
    pub throughput: Option<f64>,
    pub throughput_unit: Option<String>,
}

/// Where and how a suite result was produced.
#[derive(Clone, Debug, PartialEq)]
pub struct RunFingerprint {
    pub git_commit: String,
    /// Available parallelism on the measuring machine.
    pub threads: usize,
    pub os: String,
}

/// The `BENCH_<suite>.json` payload (schema v[`SCHEMA_VERSION`]).
#[derive(Clone, Debug, PartialEq)]
pub struct SuiteResult {
    pub schema_version: u64,
    pub suite: String,
    pub quick: bool,
    pub fingerprint: RunFingerprint,
    pub benches: Vec<BenchRecord>,
}

impl BenchRecord {
    pub fn to_json(&self) -> Value {
        minjson::obj(vec![
            ("name", minjson::s(&self.name)),
            ("iters", minjson::num(self.iters as f64)),
            ("mean_s", fnum(self.mean_s)),
            ("p50_s", fnum(self.p50_s)),
            ("min_s", fnum(self.min_s)),
            ("std_s", fnum(self.std_s)),
            ("throughput", self.throughput.map(fnum).unwrap_or(Value::Null)),
            (
                "throughput_unit",
                self.throughput_unit.as_deref().map(minjson::s).unwrap_or(Value::Null),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<BenchRecord> {
        let throughput = match v.get("throughput") {
            Value::Null => None,
            other => Some(read_f64(other).context("bench record bad \"throughput\"")?),
        };
        let throughput_unit = match v.get("throughput_unit") {
            Value::Null => None,
            other => Some(
                other
                    .as_str()
                    .map(str::to_string)
                    .context("bench record bad \"throughput_unit\"")?,
            ),
        };
        Ok(BenchRecord {
            name: field::string(v, "name")?,
            iters: field::size(v, "iters")?,
            mean_s: field::f64(v, "mean_s")?,
            p50_s: field::f64(v, "p50_s")?,
            min_s: field::f64(v, "min_s")?,
            std_s: field::f64(v, "std_s")?,
            throughput,
            throughput_unit,
        })
    }
}

impl SuiteResult {
    pub fn to_json(&self) -> Value {
        minjson::obj(vec![
            ("schema_version", minjson::num(self.schema_version as f64)),
            ("suite", minjson::s(&self.suite)),
            ("quick", Value::Bool(self.quick)),
            (
                "fingerprint",
                minjson::obj(vec![
                    ("git_commit", minjson::s(&self.fingerprint.git_commit)),
                    ("threads", minjson::num(self.fingerprint.threads as f64)),
                    ("os", minjson::s(&self.fingerprint.os)),
                ]),
            ),
            (
                "benches",
                Value::Arr(self.benches.iter().map(|b| b.to_json()).collect()),
            ),
        ])
    }

    /// Inverse of [`SuiteResult::to_json`]; rejects unknown schema
    /// versions rather than diffing incompatible data.
    pub fn from_json(v: &Value) -> Result<SuiteResult> {
        let version = field::unsigned(v, "schema_version")?;
        if version != SCHEMA_VERSION {
            bail!("bench schema version {version} is not supported (expected {SCHEMA_VERSION})");
        }
        let fp = v.get("fingerprint");
        Ok(SuiteResult {
            schema_version: version,
            suite: field::string(v, "suite")?,
            quick: field::boolean(v, "quick")?,
            fingerprint: RunFingerprint {
                git_commit: field::string(fp, "git_commit")?,
                threads: field::size(fp, "threads")?,
                os: field::string(fp, "os")?,
            },
            benches: v
                .get("benches")
                .as_arr()
                .context("bench result missing \"benches\"")?
                .iter()
                .map(BenchRecord::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

// ---------------------------------------------------------------------
// baseline diff
// ---------------------------------------------------------------------

/// One bench's current-vs-baseline ratio.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchDelta {
    pub name: String,
    pub baseline_mean_s: f64,
    pub current_mean_s: f64,
    /// `current / baseline` mean time — above 1 is slower.
    pub ratio: f64,
}

/// The result of diffing a suite run against a baseline file.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Comparison {
    /// Every bench present in both results, in current-result order.
    pub deltas: Vec<BenchDelta>,
    /// Deltas whose ratio exceeded the fail-over threshold.
    pub regressions: Vec<BenchDelta>,
    /// Benches measured now but absent from the baseline (baseline needs a
    /// refresh before the gate covers them).
    pub only_in_current: Vec<String>,
    /// Baseline entries no longer registered.
    pub only_in_baseline: Vec<String>,
}

/// Diff `current` against `baseline` by bench name. A bench regresses when
/// `current.mean_s / baseline.mean_s > fail_over`; non-finite or
/// non-positive baselines yield no verdict (reported in `deltas` with a
/// NaN ratio, never as a regression), mirroring how `scoring.rs`
/// quarantines non-finite inputs instead of letting them poison the rest.
pub fn compare(current: &SuiteResult, baseline: &SuiteResult, fail_over: f64) -> Comparison {
    let mut out = Comparison::default();
    let base: BTreeMap<&str, &BenchRecord> =
        baseline.benches.iter().map(|b| (b.name.as_str(), b)).collect();
    for b in &current.benches {
        let Some(bl) = base.get(b.name.as_str()) else {
            out.only_in_current.push(b.name.clone());
            continue;
        };
        let ratio = if bl.mean_s.is_finite() && bl.mean_s > 0.0 && b.mean_s.is_finite() {
            b.mean_s / bl.mean_s
        } else {
            f64::NAN
        };
        let delta = BenchDelta {
            name: b.name.clone(),
            baseline_mean_s: bl.mean_s,
            current_mean_s: b.mean_s,
            ratio,
        };
        if ratio.is_finite() && ratio > fail_over {
            out.regressions.push(delta.clone());
        }
        out.deltas.push(delta);
    }
    for b in &baseline.benches {
        if !current.benches.iter().any(|c| c.name == b.name) {
            out.only_in_baseline.push(b.name.clone());
        }
    }
    out
}

// ---------------------------------------------------------------------
// the benchmarks
// ---------------------------------------------------------------------

fn mk_grad(rng: &mut Rng, c: usize, p_pad: usize) -> SparseGrad {
    SparseGrad {
        vals: (0..c).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        idx: (0..c).map(|_| rng.below(p_pad as u64) as i32).collect(),
    }
}

/// Sparse DeMo aggregation (scatter-add) at aggregation size `g`,
/// coefficient count `c`, dense space `p_pad`.
fn bench_aggregate(ctx: &BenchCtx, g: usize, c: usize, p_pad: usize) -> Result<Option<BenchOutcome>> {
    let mut rng = Rng::new(1);
    let grads: Vec<SparseGrad> = (0..g).map(|_| mk_grad(&mut rng, c, p_pad)).collect();
    let refs: Vec<(&SparseGrad, f64)> = grads.iter().map(|gr| (gr, 1.0 / g as f64)).collect();
    let mut dense = vec![0.0f32; p_pad];
    let opts = AggregateOpts::default();
    let timing = time_it(ctx.warmup(3), ctx.iters(20), || {
        dense.iter_mut().for_each(|x| *x = 0.0);
        aggregate_into(&refs, &mut dense, &opts);
    });
    let mcoeff_per_s = (g * c) as f64 / timing.mean_s.max(1e-12) / 1e6;
    Ok(Some(BenchOutcome { timing, throughput: Some((mcoeff_per_s, "Mcoeff/s")) }))
}

/// Wire encode or decode (+ SHA-256 integrity) at coefficient count `c`.
fn bench_wire(ctx: &BenchCtx, c: usize, encode: bool) -> Result<Option<BenchOutcome>> {
    let mut rng = Rng::new(2);
    let sub = Submission {
        uid: 3,
        round: 17,
        grad: mk_grad(&mut rng, c, 10_000_000),
        probe: vec![0.5; 150],
    };
    let bytes = sub.encode();
    let timing = if encode {
        time_it(ctx.warmup(3), ctx.iters(30), || {
            let _ = sub.encode();
        })
    } else {
        time_it(ctx.warmup(3), ctx.iters(30), || {
            let _ = Submission::decode(&bytes).expect("valid bytes");
        })
    };
    let mb_per_s = bytes.len() as f64 / timing.mean_s.max(1e-12) / 1e6;
    Ok(Some(BenchOutcome { timing, throughput: Some((mb_per_s, "MB/s")) }))
}

/// One OpenSkill Plackett–Luce match update over 16 peers.
fn bench_openskill(ctx: &BenchCtx) -> Result<Option<BenchOutcome>> {
    let model = PlackettLuce::default();
    let ratings: Vec<Rating> = (0..16).map(|_| model.initial()).collect();
    let mut rng = Rng::new(3);
    let scores: Vec<f64> = (0..16).map(|_| rng.next_f64()).collect();
    let timing = time_it(ctx.warmup(5), ctx.iters(200), || {
        let _ = model.rate_by_scores(&ratings, &scores);
    });
    Ok(Some(BenchOutcome { timing, throughput: None }))
}

/// A Yuma consensus epoch at deployed scale: 64 validators x 256 peers.
#[allow(deprecated)] // pins the dense shim (now a forwarder into the sparse path)
fn bench_yuma(ctx: &BenchCtx) -> Result<Option<BenchOutcome>> {
    let (n_val, n_peer) = (64usize, 256usize);
    let mut rng = Rng::new(4);
    let w: Vec<Vec<f64>> =
        (0..n_val).map(|_| (0..n_peer).map(|_| rng.next_f64()).collect()).collect();
    let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 100.0)).collect();
    let timing = time_it(ctx.warmup(2), ctx.iters(10), || {
        let _ = yuma_consensus(&w, &stake, &YumaParams::default());
    });
    Ok(Some(BenchOutcome { timing, throughput: None }))
}

/// Full `Chain::run_epoch` at a registered/active shape: `n_reg` uids on
/// the table, `active` of them carrying committed weight from each of
/// `n_val` staked validators. The sparse epoch must scale with the active
/// set — the 1M shape's dense validator×table matrix would be ~128 GB,
/// while the sparse union is 1k columns whatever the table size.
fn bench_chain_epoch(
    ctx: &BenchCtx,
    n_reg: u32,
    active: u32,
    n_val: u32,
) -> Result<Option<BenchOutcome>> {
    let mut chain = Chain::new();
    let mut validators = Vec::with_capacity(n_val as usize);
    for v in 0..n_val {
        let uid = chain.register(&format!("val-{v}"))?;
        chain.add_stake(uid, 100.0 + v as f64)?;
        validators.push(uid);
    }
    for i in 0..n_reg {
        chain.register(&format!("peer-{i}"))?;
    }
    // Active uids stride across the whole table so the sparse path cannot
    // win by accidental locality.
    let stride = (n_reg / active).max(1);
    let mut rng = Rng::new(9);
    let weights: Vec<Vec<(Uid, f64)>> = validators
        .iter()
        .map(|_| (0..active).map(|i| (n_val + i * stride, rng.range_f64(0.1, 1.0))).collect())
        .collect();
    for (v, w) in validators.iter().zip(&weights) {
        chain.set_weights(*v, w)?;
    }
    let timing = time_it(ctx.warmup(1), ctx.iters(10), || {
        let _ = chain.run_epoch();
    });
    let kuid_per_s = active as f64 / timing.mean_s.max(1e-12) / 1e3;
    Ok(Some(BenchOutcome { timing, throughput: Some((kuid_per_s, "kuid/s")) }))
}

/// Deterministic assigned-shard generation (the data a peer must train on).
fn bench_corpus(ctx: &BenchCtx) -> Result<Option<BenchOutcome>> {
    let corpus = Corpus::new(4096, 0);
    let timing = time_it(ctx.warmup(3), ctx.iters(50), || {
        let _ = corpus.assigned_shard(3, 17, 0, 4, 129);
    });
    let mtok_per_s = 4.0 * 129.0 / timing.mean_s.max(1e-12) / 1e6;
    Ok(Some(BenchOutcome { timing, throughput: Some((mtok_per_s, "Mtok/s")) }))
}

/// Raw dispatch overhead of the persistent worker pool: scatter 16 tiny
/// deterministic jobs over 4 workers and wait for the scope. This is the
/// structural cost `runtime::pool` replaced per-stage `thread::scope`
/// spawn/join with — the bench pins it so the pool's queue/latch path
/// never regresses back toward thread-creation cost.
fn bench_pool_dispatch(ctx: &BenchCtx) -> Result<Option<BenchOutcome>> {
    const JOBS: usize = 16;
    let pool = WorkerPool::new(4);
    let mut items: Vec<u64> = (0..JOBS as u64).collect();
    let timing = time_it(ctx.warmup(10), ctx.iters(500), || {
        // Width == len: one job per item, the smallest unit the round
        // pipeline dispatches, with just enough arithmetic that the job
        // body is not optimized to nothing.
        let sums = pool.scatter(&mut items, JOBS, |base, chunk| {
            chunk
                .iter_mut()
                .for_each(|x| *x = x.wrapping_mul(0x9E37_79B9).rotate_left(7));
            base as u64 + chunk.iter().copied().fold(0u64, u64::wrapping_add)
        });
        std::hint::black_box(sums);
    });
    let jobs_per_s = JOBS as f64 / timing.mean_s.max(1e-12);
    Ok(Some(BenchOutcome { timing, throughput: Some((jobs_per_s, "jobs/s")) }))
}

/// One validator's fast-evaluation sweep over 32 submitted peers (windowed
/// GET + decode + structural checks + SyncScore), at the given fan-out
/// (chunks dispatched on a persistent pool, as in the live round
/// pipeline).
fn bench_fasteval(ctx: &BenchCtx, fanout: usize) -> Result<Option<BenchOutcome>> {
    const N: usize = 32;
    const COEFF: usize = 1312;
    const PADDED: usize = 167_936;
    let round = 4u64;
    let model = ProviderModel { mean_upload_ms: 100.0, jitter_ms: 0.0, ..Default::default() };
    let store = ObjectStore::new(model, 9);
    let probe = vec![0.25f32, -0.75];
    let mut rng = Rng::new(5);
    let mut peers: Vec<(Uid, ReadKey)> = Vec::with_capacity(N);
    for uid in 0..N as u32 {
        let bucket = format!("peer-{uid}");
        let rk = store.create_bucket(&bucket, &bucket);
        let sub = Submission {
            uid,
            round,
            grad: mk_grad(&mut rng, COEFF, PADDED),
            probe: probe.clone(),
        };
        store
            .put(&bucket, &bucket, &Submission::object_key(uid, round), sub.encode(), 400)
            .expect("seeding the bench store");
        peers.push((uid, rk));
    }
    let checks = RoundChecks {
        round,
        coeff_count: COEFF,
        padded_count: PADDED,
        probe_len: probe.len(),
        validator_probe: &probe,
        lr: 0.02,
        sync_threshold: 3.0,
        window: (200, 2_000),
        reader: 0,
        retry: crate::storage::RetryPolicy::default(),
    };
    let pool = WorkerPool::new(fanout);
    let timing = time_it(ctx.warmup(2), ctx.iters(30), || {
        let _ = fast_evaluate_all(&store, &peers, &checks, &pool, fanout).expect("fast eval");
    });
    let peers_per_s = N as f64 / timing.mean_s.max(1e-12);
    Ok(Some(BenchOutcome { timing, throughput: Some((peers_per_s, "peers/s")) }))
}

/// The tentpole path: full communication rounds (peer turns, per-validator
/// fast-eval fan-out + primary evaluation, chain epoch, aggregation) on the
/// SimExec backend at a fixed worker-thread count. Determinism across
/// thread counts is pinned by `tests/parallel_determinism.rs`; this only
/// measures.
fn bench_round_pipeline(
    ctx: &BenchCtx,
    threads: usize,
    get_fail: f64,
) -> Result<Option<BenchOutcome>> {
    let (model, n_peers, rounds, reps) =
        if ctx.quick { ("nano", 8usize, 2u64, 2usize) } else { ("mid", 32, 3, 3) };
    let mk_run = || {
        let peers: Vec<Behavior> = (0..n_peers)
            .map(|i| match i % 8 {
                6 => Behavior::Freeloader,
                7 => Behavior::Poisoner { scale: 100.0 },
                _ => Behavior::Honest { data_mult: 1.0 },
            })
            .collect();
        let mut cfg = RunConfig {
            model: model.to_string(),
            rounds,
            peers,
            ..RunConfig::default()
        };
        cfg.eval_every = 0;
        cfg.seed = 11;
        cfg.n_validators = 2;
        cfg.params.top_g = 8;
        cfg.params.eval_sample = 4;
        cfg.threads = threads;
        // Nonzero GET-failure probability routes every fast-eval read
        // through the retry/backoff path, so the chaos variant prices
        // the fault plane rather than the happy path.
        cfg.provider.get_fail_prob = get_fail;
        GauntletBuilder::sim().config(cfg).build().expect("sim run")
    };
    // Pre-build one run per timing iteration (plus warmup) so construction
    // cost stays out of the timed region.
    let mut prebuilt: Vec<_> = (0..reps + 1).map(|_| mk_run()).collect();
    let timing = time_it(1, reps, || {
        let mut run = prebuilt.pop().expect("prebuilt run");
        for _ in 0..rounds {
            run.run_round().expect("round");
        }
    });
    let rounds_per_s = rounds as f64 / timing.mean_s.max(1e-12);
    Ok(Some(BenchOutcome { timing, throughput: Some((rounds_per_s, "rounds/s")) }))
}

// ---------------------------------------------------------------------
// kernel-level shapes (VectorLane)
// ---------------------------------------------------------------------

/// The mid-model SimExec (60k params) plus its initial parameters and a
/// deterministic token set — the shared fixture for the kernel benches.
fn kernel_fixture() -> (SimExec, Vec<f32>, Vec<i32>) {
    let exec = SimExec::new(&SimSpec::mid(), 7);
    let theta = exec.init_params().expect("init params");
    let toks = kernel_tokens(&exec, 5);
    (exec, theta, toks)
}

/// One full token batch (`batch * (seq + 1)` ids), varied by `tag` so
/// multi-case benches exercise distinct `u_T` directions.
fn kernel_tokens(exec: &SimExec, tag: i32) -> Vec<i32> {
    let m = exec.meta();
    let n = m.batch * (m.seq + 1);
    (0..n as i32).map(|i| (i * 31 + tag) % m.vocab as i32).collect()
}

/// Dense ±1 sign-pattern coefficient vectors (full padded width), one per
/// candidate, each with a distinct phase so nothing folds away.
fn kernel_coeffs(exec: &SimExec, n: usize) -> Vec<Vec<f32>> {
    let padded = exec.meta().padded_count;
    (0..n)
        .map(|c| (0..padded).map(|i| if (i + c) % 3 == 0 { 1.0 } else { -1.0 }).collect())
        .collect()
}

/// The fused loss+gradient lane kernel, writing into a reused buffer —
/// the inner loop of every honest peer's training step.
fn bench_kernel_grad(ctx: &BenchCtx) -> Result<Option<BenchOutcome>> {
    let (exec, theta, toks) = kernel_fixture();
    let mut g = Vec::new();
    let timing = time_it(ctx.warmup(5), ctx.iters(200), || {
        let _ = exec.grad_into(&theta, &toks, &mut g).expect("grad_into");
        std::hint::black_box(&g);
    });
    let mparam_per_s = theta.len() as f64 / timing.mean_s.max(1e-12) / 1e6;
    Ok(Some(BenchOutcome { timing, throughput: Some((mparam_per_s, "Mparam/s")) }))
}

/// `loss_delta` at one candidate (the per-call kernel) or `n_cand`
/// candidates in one `loss_delta_batch` call sharing the token direction —
/// the validator's primary-evaluation inner loop. Throughput counts every
/// candidate's full parameter sweep, so the single and batched variants
/// are directly comparable.
fn bench_kernel_loss_delta(ctx: &BenchCtx, n_cand: usize) -> Result<Option<BenchOutcome>> {
    let (exec, theta, toks) = kernel_fixture();
    let coeffs = kernel_coeffs(&exec, n_cand);
    let cands: Vec<(&[f32], f32)> = coeffs
        .iter()
        .enumerate()
        .map(|(i, c)| (c.as_slice(), 0.01 + i as f32 * 1e-4))
        .collect();
    let timing = if n_cand == 1 {
        time_it(ctx.warmup(5), ctx.iters(200), || {
            let _ =
                exec.loss_delta(&theta, cands[0].0, cands[0].1, &toks).expect("loss_delta");
        })
    } else {
        time_it(ctx.warmup(5), ctx.iters(200), || {
            let _ = exec.loss_delta_batch(&theta, &cands, &toks).expect("loss_delta_batch");
        })
    };
    let mparam_per_s = (n_cand * theta.len()) as f64 / timing.mean_s.max(1e-12) / 1e6;
    Ok(Some(BenchOutcome { timing, throughput: Some((mparam_per_s, "Mparam/s")) }))
}

/// The pre-VectorLane scalar `loss_delta` (sequential f64 accumulators),
/// kept as a registered reference so one suite run shows the lane
/// kernels' speedup as a same-machine ratio against
/// `kernel_loss_delta_mid`, rather than across baseline files.
fn bench_kernel_loss_delta_scalar(ctx: &BenchCtx) -> Result<Option<BenchOutcome>> {
    let (exec, theta, toks) = kernel_fixture();
    let coeffs = kernel_coeffs(&exec, 1);
    let timing = time_it(ctx.warmup(5), ctx.iters(200), || {
        let _ = exec
            .loss_delta_scalar_ref(&theta, &coeffs[0], 0.01, &toks)
            .expect("loss_delta_scalar_ref");
    });
    let mparam_per_s = theta.len() as f64 / timing.mean_s.max(1e-12) / 1e6;
    Ok(Some(BenchOutcome { timing, throughput: Some((mparam_per_s, "Mparam/s")) }))
}

/// `eval_peer_batch` over `n_cases` peers with distinct coefficient
/// vectors and distinct assigned/random token sets — the exact shape
/// `PrimaryEvaluator::evaluate_batch` hands the backend each round.
fn bench_kernel_eval_peer(ctx: &BenchCtx, n_cases: usize) -> Result<Option<BenchOutcome>> {
    let (exec, theta, _) = kernel_fixture();
    let coeffs = kernel_coeffs(&exec, n_cases);
    let tok_sets: Vec<(Vec<i32>, Vec<i32>)> = (0..n_cases as i32)
        .map(|c| (kernel_tokens(&exec, 2 * c), kernel_tokens(&exec, 2 * c + 1)))
        .collect();
    let cases: Vec<EvalPeerCase<'_>> = coeffs
        .iter()
        .zip(&tok_sets)
        .map(|(c, (a, r))| EvalPeerCase { coeff: c, tok_assigned: a, tok_rand: r })
        .collect();
    let timing = time_it(ctx.warmup(3), ctx.iters(100), || {
        let _ = exec.eval_peer_batch(&theta, 0.01, &cases).expect("eval_peer_batch");
    });
    let evals_per_s = n_cases as f64 / timing.mean_s.max(1e-12);
    Ok(Some(BenchOutcome { timing, throughput: Some((evals_per_s, "evals/s")) }))
}

// ---------------------------------------------------------------------
// XLA extras (not part of the registry: artifact- and machine-dependent,
// so they are printed for humans rather than diffed against baselines)
// ---------------------------------------------------------------------

/// Time the compiled-artifact round-trips (loss / grad / demo_compress /
/// apply_update / eval_peer) for every available config. No-op when no
/// artifacts are built — the `hotpath` bench binary calls this after the
/// registered suite.
pub fn xla_extras() -> Result<()> {
    use crate::runtime::{artifact_dir, artifacts_available, Executor};
    let mut table = Table::new("XLA artifact round-trips", &["operation", "mean", "throughput"]);
    let mut any = false;
    for cfg in ["nano", "tiny"] {
        if !artifacts_available(cfg) {
            continue;
        }
        // Artifacts exist but may not be executable (stub xla crate);
        // skip rather than fail the whole bench.
        let exec = match Executor::load(artifact_dir(cfg)) {
            Ok(e) => e,
            Err(e) => {
                println!("[skipping xla {cfg} benches: {e:#}]");
                continue;
            }
        };
        any = true;
        let meta = exec.meta.clone();
        let theta = exec.init_params()?;
        let corpus = Corpus::new(meta.vocab as u32, 0);
        let toks = corpus.assigned_shard(1, 0, 0, meta.batch, meta.seq + 1);
        let iters = if cfg == "nano" { 10 } else { 5 };

        let tl = time_it(2, iters, || {
            let _ = exec.loss(&theta, &toks).unwrap();
        });
        let tg = time_it(2, iters, || {
            let _ = exec.grad(&theta, &toks).unwrap();
        });
        let e = vec![0.0f32; meta.param_count];
        let (_, g) = exec.grad(&theta, &toks)?;
        let tc = time_it(2, iters, || {
            let _ = exec.demo_compress(&e, &g, 0.999).unwrap();
        });
        let coeff = vec![0.01f32; meta.padded_count];
        let ta = time_it(2, iters, || {
            let _ = exec.apply_update(&theta, &coeff, 0.02).unwrap();
        });
        let te = time_it(2, iters, || {
            let _ = exec.eval_peer(&theta, &coeff, 0.01, &toks, &toks).unwrap();
        });
        for (name, timing) in [
            ("loss", &tl),
            ("grad", &tg),
            ("demo_compress", &tc),
            ("apply_update", &ta),
            ("eval_peer", &te),
        ] {
            let toks_per_s = (meta.batch * meta.seq) as f64 / timing.mean_s.max(1e-12);
            table.row(&[
                format!("xla {cfg}/{name}"),
                human_duration(timing.mean_s),
                if name == "loss" || name == "grad" {
                    format!("{:.1} ktok/s", toks_per_s / 1e3)
                } else {
                    String::new()
                },
            ]);
        }
    }
    if any {
        table.print();
    } else {
        println!("[no compiled artifacts found; xla round-trip benches skipped]");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, mean: f64) -> BenchRecord {
        BenchRecord {
            name: name.to_string(),
            iters: 5,
            mean_s: mean,
            p50_s: mean,
            min_s: mean * 0.9,
            std_s: mean * 0.05,
            throughput: Some(1.0 / mean),
            throughput_unit: Some("ops/s".to_string()),
        }
    }

    fn result(benches: Vec<BenchRecord>) -> SuiteResult {
        SuiteResult {
            schema_version: SCHEMA_VERSION,
            suite: "hotpath".to_string(),
            quick: true,
            fingerprint: RunFingerprint {
                git_commit: "deadbeef".to_string(),
                threads: 8,
                os: "linux".to_string(),
            },
            benches,
        }
    }

    #[test]
    fn identical_baseline_has_no_regressions() {
        let r = result(vec![rec("a", 1e-3), rec("b", 2e-3)]);
        let cmp = compare(&r, &r, 1.25);
        assert_eq!(cmp.deltas.len(), 2);
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert!((cmp.deltas[0].ratio - 1.0).abs() < 1e-12);
        assert!(cmp.only_in_current.is_empty() && cmp.only_in_baseline.is_empty());
    }

    #[test]
    fn synthetic_2x_slowdown_is_flagged() {
        let base = result(vec![rec("a", 1e-3), rec("b", 2e-3)]);
        let mut cur = base.clone();
        cur.benches[0].mean_s = 2e-3; // a regressed 2x
        let cmp = compare(&cur, &base, 1.5);
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].name, "a");
        assert!((cmp.regressions[0].ratio - 2.0).abs() < 1e-9);
    }

    #[test]
    fn improvements_and_noise_pass_the_gate() {
        let base = result(vec![rec("a", 1e-3), rec("b", 2e-3)]);
        let mut cur = base.clone();
        cur.benches[0].mean_s = 0.5e-3; // 2x faster
        cur.benches[1].mean_s = 2.2e-3; // 1.1x slower: below 1.25
        let cmp = compare(&cur, &base, 1.25);
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
    }

    #[test]
    fn name_mismatches_are_reported_not_fatal() {
        let base = result(vec![rec("a", 1e-3), rec("gone", 1e-3)]);
        let cur = result(vec![rec("a", 1e-3), rec("new", 1e-3)]);
        let cmp = compare(&cur, &base, 1.25);
        assert_eq!(cmp.only_in_current, vec!["new".to_string()]);
        assert_eq!(cmp.only_in_baseline, vec!["gone".to_string()]);
        assert_eq!(cmp.deltas.len(), 1);
    }

    #[test]
    fn degenerate_baselines_never_regress() {
        let base = result(vec![rec("zero", 0.0), rec("nan", f64::NAN)]);
        let cur = result(vec![rec("zero", 1.0), rec("nan", 1.0)]);
        let cmp = compare(&cur, &base, 1.25);
        assert!(cmp.regressions.is_empty());
        assert!(cmp.deltas.iter().all(|d| d.ratio.is_nan()));
    }

    #[test]
    fn schema_roundtrips_through_minjson() {
        let mut r = result(vec![rec("a", 1e-3)]);
        // Awkward values must survive: no throughput, -0.0 std.
        r.benches.push(BenchRecord {
            name: "bare".to_string(),
            iters: 1,
            mean_s: 0.25,
            p50_s: 0.25,
            min_s: 0.25,
            std_s: -0.0,
            throughput: None,
            throughput_unit: None,
        });
        let text = r.to_json().write();
        let parsed = Value::parse(&text).expect("schema JSON parses");
        let back = SuiteResult::from_json(&parsed).expect("typed reload");
        assert_eq!(r, back);
        assert_eq!(text, back.to_json().write(), "serialization is idempotent");
    }

    #[test]
    fn from_json_rejects_bad_versions_and_shapes() {
        let mut r = result(vec![]);
        r.schema_version = SCHEMA_VERSION + 1;
        let v = Value::parse(&r.to_json().write()).unwrap();
        assert!(SuiteResult::from_json(&v).is_err(), "future schema rejected");
        let v = Value::parse(r#"{"schema_version":1,"suite":"x"}"#).unwrap();
        assert!(SuiteResult::from_json(&v).is_err(), "missing fields rejected");
    }

    #[test]
    fn registry_names_are_unique_and_hotpath_exists() {
        for s in registry() {
            let mut names: Vec<&str> = s.benches.iter().map(|b| b.name).collect();
            let n = names.len();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), n, "duplicate bench name in suite {}", s.name);
        }
        assert!(find_suite("hotpath").is_some());
        assert!(find_suite("nope").is_none());
    }
}
