//! The paper-figure reproductions the `rust/benches/` binaries wrap.
//!
//! Each `[[bench]]` target used to carry its whole reproduction inline;
//! they are now thin argument-parsing wrappers over these library
//! functions, so the table/series/persistence logic lives in one place
//! (and can be driven programmatically — e.g. from future `gauntlet`
//! subcommands) instead of five binaries:
//!
//! - [`fig1`] — Templar permissionless loss curve vs AdamW DDP baseline.
//! - [`fig2`] — LossScore / LossRating evolution for three peer types.
//! - [`table1`] — downstream zero-shot eval of both checkpoints.
//! - [`ablations`] — the §3.1/§3.2/§3.3/§4 design-choice studies.
//!
//! All four need compiled artifacts (they reproduce the paper's numbers on
//! the real model) and print a note instead of failing when artifacts are
//! missing. The microbenchmark suite lives in [`super::suite`].

use anyhow::Result;

use super::{save_json, series_json, sparkline, Table};
use crate::coordinator::baseline::{AdamWParams, AdamWTrainer};
use crate::coordinator::engine::GauntletBuilder;
use crate::coordinator::fast_eval::sync_score;
use crate::coordinator::run::RunConfig;
use crate::coordinator::scoring::normalize_scores;
use crate::data::Corpus;
use crate::demo::aggregate::{aggregate, AggregateOpts};
use crate::demo::SparseGrad;
use crate::eval::{evaluate_suite, Suite};
use crate::minjson::{self, Value};
use crate::peers::Behavior;
use crate::runtime::{artifact_dir, artifacts_available, Executor};
use crate::util::{mean, std_dev, Rng};

/// Fig. 1: Gauntlet permissionless run vs centralized AdamW DDP at `nano`
/// scale — heldout-loss curves, token counts, and `bench_results/fig1.json`.
pub fn fig1(rounds: u64) -> Result<()> {
    if !artifacts_available("nano") {
        println!("fig1: artifacts missing; run `make artifacts` first");
        return Ok(());
    }
    // Incentivized population: data multipliers above 1 are what the
    // incentive buys the network (paper §6: "participants were successfully
    // incentivized to process more data").
    let peers = vec![
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Honest { data_mult: 1.5 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Freeloader,
    ];
    let n_workers = 5;

    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds,
        peers,
        ..RunConfig::default()
    };
    cfg.eval_every = 2;
    cfg.params.top_g = 4;
    println!("fig1: gauntlet ({} peers) vs adamw ({} workers), {rounds} rounds", 6, n_workers);

    let mut run = GauntletBuilder::artifact().config(cfg).build()?;
    let mut g_curve = Vec::new();
    let mut tokens_gauntlet: u64 = 0;
    for _ in 0..rounds {
        let rec = run.run_round()?;
        tokens_gauntlet += rec.tokens_processed;
        if let Some(l) = rec.heldout_loss {
            g_curve.push((rec.round as f64, l));
        }
    }

    let exec = Executor::load(artifact_dir("nano"))?;
    let corpus = Corpus::new(exec.meta.vocab as u32, 0);
    let mut trainer = AdamWTrainer::new(exec.init_params()?, AdamWParams::default(), n_workers);
    let mut a_curve = Vec::new();
    let mut tokens_adamw: u64 = 0;
    for r in 0..rounds {
        trainer.step(&exec, &corpus, r)?;
        tokens_adamw += (n_workers * exec.meta.batch * exec.meta.seq) as u64;
        if r % 2 == 0 {
            let toks = corpus.heldout(0, exec.meta.batch, exec.meta.seq + 1);
            a_curve.push((r as f64, exec.loss(&trainer.theta, &toks)? as f64));
        }
    }

    let gl: Vec<f64> = g_curve.iter().map(|(_, y)| *y).collect();
    let al: Vec<f64> = a_curve.iter().map(|(_, y)| *y).collect();
    let mut t =
        Table::new("Fig. 1 — heldout loss by round", &["round", "templar (gauntlet)", "adamw ddp"]);
    for (i, (r, gy)) in g_curve.iter().enumerate() {
        let ay = a_curve.get(i).map(|(_, y)| format!("{y:.4}")).unwrap_or_default();
        t.row(&[format!("{r}"), format!("{gy:.4}"), ay]);
    }
    t.print();
    println!("  templar {}", sparkline(&gl, 50));
    println!("  adamw   {}", sparkline(&al, 50));
    println!(
        "  tokens: templar={tokens_gauntlet} adamw={tokens_adamw} (incentivized peers processed {:.2}x)",
        tokens_gauntlet as f64 / tokens_adamw as f64
    );
    println!(
        "  final: templar={:.4} adamw={:.4}",
        gl.last().unwrap(),
        al.last().unwrap()
    );

    save_json(
        "fig1",
        &minjson::obj(vec![
            ("gauntlet", series_json(&g_curve)),
            ("adamw", series_json(&a_curve)),
            ("tokens_gauntlet", minjson::num(tokens_gauntlet as f64)),
            ("tokens_adamw", minjson::num(tokens_adamw as f64)),
        ]),
    );
    Ok(())
}

/// Fig. 2: LossScore / LossRating evolution for three peer types — 2x-data,
/// desynchronized (3-round pause), and baseline — each evaluated every
/// round (S = K, the paper's controlled simulation).
pub fn fig2(rounds: u64) -> Result<()> {
    if !artifacts_available("nano") {
        println!("fig2: artifacts missing; run `make artifacts` first");
        return Ok(());
    }
    let desync_at = 5;

    let peers = vec![
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Desync { at: desync_at, pause: 3 },
        Behavior::Honest { data_mult: 1.0 },
    ];
    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds,
        peers,
        ..RunConfig::default()
    };
    cfg.params.eval_sample = 3;
    cfg.params.top_g = 3;
    cfg.eval_every = 0;

    let mut run = GauntletBuilder::artifact().config(cfg).build()?;
    let labels = ["2x-data", "desync", "baseline"];
    let mut scores: Vec<Vec<Option<f64>>> = vec![Vec::new(); 3];
    let mut ratings: Vec<Vec<f64>> = vec![Vec::new(); 3];
    for _ in 0..rounds {
        let rec = run.run_round()?;
        for (i, p) in rec.peers.iter().enumerate() {
            scores[i].push(p.loss_score_rand);
            ratings[i].push(p.rating_mu);
        }
    }

    let mut t = Table::new(
        "Fig. 2 — per-round LossScore (rand) / LossRating",
        &["peer", "score mean", "score std", "rating start", "rating end", "rating sparkline"],
    );
    for i in 0..3 {
        let s: Vec<f64> = scores[i].iter().flatten().copied().collect();
        t.row(&[
            labels[i].to_string(),
            format!("{:+.4}", mean(&s)),
            format!("{:.4}", std_dev(&s)),
            format!("{:.2}", ratings[i].first().unwrap()),
            format!("{:.2}", ratings[i].last().unwrap()),
            sparkline(&ratings[i], 30),
        ]);
    }
    t.print();

    // Shape assertions (reported, not fatal — this is a bench).
    let end = |i: usize| *ratings[i].last().unwrap();
    println!("\nshape check (paper Fig. 2):");
    println!(
        "  2x-data rating > baseline rating: {} ({:.2} vs {:.2})",
        end(0) > end(2),
        end(0),
        end(2)
    );
    println!(
        "  desync rating < baseline rating:  {} ({:.2} vs {:.2})",
        end(1) < end(2),
        end(1),
        end(2)
    );
    let noisy = {
        let s: Vec<f64> = scores[2].iter().flatten().copied().collect();
        std_dev(&s) > 0.1 * mean(&s).abs()
    };
    println!("  LossScore noisy round-to-round:   {noisy}");

    save_json(
        "fig2",
        &minjson::obj(vec![(
            "peers",
            Value::Arr(
                (0..3)
                    .map(|i| {
                        minjson::obj(vec![
                            ("label", minjson::s(labels[i])),
                            (
                                "scores",
                                Value::Arr(
                                    scores[i]
                                        .iter()
                                        .map(|o| o.map(minjson::num).unwrap_or(Value::Null))
                                        .collect(),
                                ),
                            ),
                            ("ratings", minjson::arr_f64(&ratings[i])),
                        ])
                    })
                    .collect(),
            ),
        )]),
    );
    Ok(())
}

/// Table 1: downstream zero-shot evaluation of the permissionless
/// checkpoint vs the AdamW-DDP checkpoint vs the untrained model.
pub fn table1(rounds: u64, items: usize) -> Result<()> {
    if !artifacts_available("nano") {
        println!("table1: artifacts missing; run `make artifacts` first");
        return Ok(());
    }
    // Train both systems on the same token budget.
    let peers = vec![Behavior::Honest { data_mult: 1.0 }; 5];
    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds,
        peers,
        ..RunConfig::default()
    };
    cfg.eval_every = 0;
    println!("table1: training templar + adamw for {rounds} rounds, then {items} items/suite");
    let mut run = GauntletBuilder::artifact().config(cfg).build()?;
    for _ in 0..rounds {
        run.run_round()?;
    }
    let theta_templar = run.theta().to_vec();

    let exec = Executor::load(artifact_dir("nano"))?;
    let corpus = Corpus::new(exec.meta.vocab as u32, 0);
    let mut trainer = AdamWTrainer::new(exec.init_params()?, AdamWParams::default(), 5);
    for r in 0..rounds {
        trainer.step(&exec, &corpus, r)?;
    }

    let theta_init = exec.init_params()?;
    let rows: Vec<(&str, &Vec<f32>)> = vec![
        ("TEMPLAR (gauntlet)", &theta_templar),
        ("AdamW DDP", &trainer.theta),
        ("untrained", &theta_init),
    ];

    let mut t = Table::new(
        "Table 1 — zero-shot acc_norm (synthetic analogues)",
        &["model", "synth-hellaswag", "synth-piqa", "synth-arc-e"],
    );
    let mut json_rows = Vec::new();
    for (name, theta) in &rows {
        let mut cells = vec![name.to_string()];
        let mut obj = vec![("model", minjson::s(name))];
        for suite in Suite::all() {
            let r = evaluate_suite(&exec, theta, &corpus, suite, items)?;
            cells.push(format!("{:.3}", r.acc_norm));
            obj.push((suite.name(), minjson::num(r.acc_norm)));
        }
        t.row(&cells);
        json_rows.push(minjson::obj(obj));
    }
    t.row(&[
        "chance".into(),
        "0.250".into(),
        "0.500".into(),
        "0.250".into(),
    ]);
    t.print();
    println!("\n(paper Table 1 shape: trained models comparable, both above chance)");
    save_json("table1", &Value::Arr(json_rows));
    Ok(())
}

/// The §3.1/§3.2/§3.3/§4 ablation studies. `which` selects sub-studies by
/// name (`beta`, `incentive`, `sync`, `byzantine`); empty runs all four.
pub fn ablations(which: &[String]) -> Result<()> {
    let all = which.is_empty();
    let has = |n: &str| all || which.iter().any(|w| w == n);

    if has("incentive") {
        ablate_incentive();
    }
    if has("byzantine") {
        ablate_byzantine();
    }
    if !artifacts_available("nano") {
        println!("\n[beta/sync ablations need artifacts; run `make artifacts`]");
        return Ok(());
    }
    let exec = Executor::load(artifact_dir("nano"))?;
    if has("sync") {
        ablate_sync(&exec)?;
    }
    if has("beta") {
        ablate_beta(&exec)?;
    }
    Ok(())
}

/// §3.3: one user with 10 GPUs as ONE strong peer vs TEN weak peers.
fn ablate_incentive() {
    // A network of peers with a spread of PEERSCOREs (weakest at 0 so the
    // eq. 5 min-shift keeps everyone's relative position). The user in
    // question either consolidates its 10 GPUs into ONE strong peer
    // (score 10) or splits them into TEN weak peers (score 1 each).
    let field = [6.0, 5.0, 4.0, 3.0, 0.0];
    let one_strong: Vec<f64> = std::iter::once(10.0).chain(field).collect();
    let ten_weak: Vec<f64> = vec![1.0; 10].into_iter().chain(field).collect();
    let mut t = Table::new(
        "§3.3 incentive concentration: one 10-GPU peer vs ten 1-GPU peers",
        &["norm power c", "share (1 strong peer)", "share (10 weak peers total)", "strong/weak"],
    );
    let mut json = Vec::new();
    for c in [1.0, 2.0, 3.0] {
        let s = normalize_scores(&one_strong, c)[0];
        let w: f64 = normalize_scores(&ten_weak, c)[..10].iter().sum();
        t.row(&[
            format!("{c}"),
            format!("{:.3}", s),
            format!("{:.3}", w),
            format!("{:.2}x", s / w.max(1e-9)),
        ]);
        json.push(minjson::obj(vec![
            ("c", minjson::num(c)),
            ("strong", minjson::num(s)),
            ("weak", minjson::num(w)),
        ]));
    }
    t.print();
    println!("(c=2, the paper's choice, rewards consolidating GPUs into one strong peer)");
    save_json("ablation_incentive", &Value::Arr(json));
}

/// §4: rescaling attack in the encoded domain, with/without normalization.
fn ablate_byzantine() {
    let mut rng = Rng::new(7);
    let p_pad = 4096;
    let c = 256;
    let mk = |rng: &mut Rng, scale: f32| SparseGrad {
        vals: (0..c).map(|_| rng.normal_f32(0.0, scale)).collect(),
        idx: (0..c).map(|_| rng.below(p_pad as u64) as i32).collect(),
    };
    let honest: Vec<SparseGrad> = (0..4).map(|_| mk(&mut rng, 1.0)).collect();
    let attacker = mk(&mut rng, 1000.0);

    let cos = |a: &[f32], b: &[f32]| {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
        let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        dot / (na * nb).max(1e-12)
    };

    let mut t = Table::new(
        "§4 rescaling attack (x1000): aggregate fidelity vs honest-only",
        &["normalization", "cosine(honest-only, with-attacker)", "attacker share of L2"],
    );
    let mut json = Vec::new();
    for normalize in [true, false] {
        let opts = AggregateOpts { normalize, ..Default::default() };
        let w = 1.0 / 5.0;
        let honest_refs: Vec<(&SparseGrad, f64)> = honest.iter().map(|g| (g, w)).collect();
        let clean = aggregate(&honest_refs, p_pad, &opts);
        let mut with_att = honest_refs.clone();
        with_att.push((&attacker, w));
        let dirty = aggregate(&with_att, p_pad, &opts);
        let att_only = aggregate(&[(&attacker, w)], p_pad, &opts);
        let att_norm: f64 = att_only.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let dirty_norm: f64 = dirty.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let fidelity = cos(&clean, &dirty);
        t.row(&[
            if normalize { "ON (paper)" } else { "OFF" }.to_string(),
            format!("{:.4}", fidelity),
            format!("{:.3}", att_norm / dirty_norm.max(1e-12)),
        ]);
        json.push(minjson::obj(vec![
            ("normalize", Value::Bool(normalize)),
            ("fidelity", minjson::num(fidelity)),
        ]));
    }
    t.print();
    println!("(normalization keeps the aggregate pointing where honest peers point)");
    save_json("ablation_byzantine", &Value::Arr(json));
}

/// §3.2: SyncScore vs actual lag in signed steps.
fn ablate_sync(exec: &Executor) -> Result<()> {
    let meta = &exec.meta;
    let mut theta = exec.init_params()?;
    let stale = theta.clone();
    let mut rng = Rng::new(3);
    // DeMo updates are momentum-correlated across adjacent rounds (error
    // feedback, decay 0.999), so a stale peer's divergence grows close to
    // linearly in lag — model that with a persistent base direction plus
    // fresh per-round noise.
    let mut base = vec![0.0f32; meta.padded_count];
    for _ in 0..meta.coeff_count {
        let i = rng.below(meta.padded_count as u64) as usize;
        base[i] += rng.normal_f32(0.0, 1.0);
    }
    let mut t = Table::new(
        "§3.2 SyncScore vs true lag (threshold = 3)",
        &["lag (rounds)", "SyncScore", "passes filter"],
    );
    let mut json = Vec::new();
    for lag in 0..=6u32 {
        let probe_peer = meta.sync_probe(&stale);
        let probe_val = meta.sync_probe(&theta);
        let s = sync_score(&probe_val, &probe_peer, 0.02);
        t.row(&[lag.to_string(), format!("{s:.3}"), (s <= 3.0).to_string()]);
        json.push(minjson::obj(vec![
            ("lag", minjson::num(lag as f64)),
            ("sync_score", minjson::num(s)),
        ]));
        // validator takes one more signed, momentum-correlated update step
        let coeff: Vec<f32> = base
            .iter()
            .map(|b| b + 0.3 * rng.normal_f32(0.0, 1.0) * (*b != 0.0) as u8 as f32)
            .collect();
        theta = exec.apply_update(&theta, &coeff, 0.02)?;
    }
    t.print();
    println!("(score grows ~linearly with lag under momentum-correlated updates; the threshold-3 filter rejects ~>=4-step-stale peers)");
    save_json("ablation_sync", &Value::Arr(json));
    Ok(())
}

/// §3.1: beta = c*alpha sweep — negative-LossScore rate and rank stability.
fn ablate_beta(exec: &Executor) -> Result<()> {
    let meta = &exec.meta;
    let corpus = Corpus::new(meta.vocab as u32, 0);
    let theta = exec.init_params()?;
    let (b, s1) = (meta.batch, meta.seq + 1);
    let lr = 0.02f32;

    // Four honest peers' pseudo-gradients with different data amounts
    // (1..4 microbatches) — ground-truth quality ranking is 4 > 3 > 2 > 1.
    let mut grads = Vec::new();
    for (uid, n_mb) in [(1u32, 1usize), (2, 2), (3, 3), (4, 4)] {
        let mut acc = vec![0.0f32; meta.param_count];
        for mb in 0..n_mb {
            let toks = corpus.assigned_shard(uid, 0, mb as u32, b, s1);
            let (_, g) = exec.grad(&theta, &toks)?;
            for (a, gi) in acc.iter_mut().zip(&g) {
                *a += gi / n_mb as f32;
            }
        }
        let e = vec![0.0f32; meta.param_count];
        let (vals, idx, _) = exec.demo_compress(&e, &acc, 0.999)?;
        let mut dense = vec![0.0f32; meta.padded_count];
        let g = SparseGrad { vals, idx };
        let n = g.l2_norm();
        g.scatter_into(&mut dense, (1.0 / n) as f32);
        grads.push(dense);
    }

    let mut t = Table::new(
        "§3.1 beta sweep (beta = c * alpha): LossScore quality over 6 data draws",
        &["c", "mean score", "score std", "neg rate", "rank stability"],
    );
    let mut json = Vec::new();
    for c in [0.25f32, 0.5, 1.0, 2.0] {
        let beta = c * lr;
        let mut all_scores: Vec<f64> = Vec::new();
        let mut orderings: Vec<Vec<usize>> = Vec::new();
        for draw in 0..6u32 {
            let tok = corpus.random_eval(1000 + draw as u64, draw, b, s1);
            let mut scores = Vec::new();
            for dense in &grads {
                let (_, _, l0, l1) = exec.eval_peer(&theta, dense, beta, &tok, &tok)?;
                scores.push(l0 as f64 - l1 as f64);
            }
            all_scores.extend(&scores);
            let mut order: Vec<usize> = (0..scores.len()).collect();
            order.sort_by(|&i, &j| scores[j].partial_cmp(&scores[i]).unwrap());
            orderings.push(order);
        }
        // rank stability: mean pairwise agreement of the top choice
        let top_counts = orderings.iter().filter(|o| o[0] == orderings[0][0]).count();
        let stability = top_counts as f64 / orderings.len() as f64;
        let neg_rate =
            all_scores.iter().filter(|s| **s < 0.0).count() as f64 / all_scores.len() as f64;
        t.row(&[
            format!("{c}"),
            format!("{:+.4}", mean(&all_scores)),
            format!("{:.4}", std_dev(&all_scores)),
            format!("{:.2}", neg_rate),
            format!("{:.2}", stability),
        ]);
        json.push(minjson::obj(vec![
            ("c", minjson::num(c as f64)),
            ("mean", minjson::num(mean(&all_scores))),
            ("std", minjson::num(std_dev(&all_scores))),
            ("neg_rate", minjson::num(neg_rate)),
            ("stability", minjson::num(stability)),
        ]));
    }
    t.print();
    println!("(paper: smaller c => fewer negative scores, more consistent rankings)");
    save_json("ablation_beta", &Value::Arr(json));
    Ok(())
}
