//! Scripted population churn: a declarative, round-indexed event schedule
//! that makes the paper's "completely permissionless" dimension a
//! first-class axis of every run.
//!
//! The fixed `RunConfig::peers` population only covers round-0
//! registration; real subnets see peers join mid-run, walk away, get
//! displaced when the slot table fills, and re-register under fresh
//! hotkeys. A [`Scenario`] scripts exactly those transitions (plus stake
//! moves and provider outages) so they are reproducible, thread-count
//! independent, and cheap to express on the CLI
//! (`gauntlet run --scenario <file|inline>`).
//!
//! # Compact form
//!
//! One event per line (or `;`-separated), `#` starts a comment:
//!
//! ```text
//! # round 3: a newcomer joins (behaviour grammar = the --peers grammar)
//! @3 join honest
//! @3 join poisoner:50
//! @5 leave 4            # uid 4 deregisters and frees its slot
//! @6 stake 0 500        # set uid 0's stake to 500 TAO
//! @7 outage 0.5 2       # 50% PUT loss for 2 rounds
//! @7 chaos get-fail 0.2 3   # 20% transient GET failure for 3 rounds
//! @7 chaos corrupt 0.05 2   # 5% of GET payloads bit-flipped for 2 rounds
//! @8 eclipse 0 5 2      # validator uid 0 cannot read peer 5 for 2 rounds
//! ```
//!
//! # JSON form
//!
//! The same schedule as data (auto-detected by a leading `{` or `[`):
//!
//! ```text
//! {"events": [
//!   {"round": 3, "event": "join", "peer": "honest"},
//!   {"round": 5, "event": "leave", "uid": 4},
//!   {"round": 6, "event": "stake", "uid": 0, "amount": 500},
//!   {"round": 7, "event": "outage", "prob": 0.5, "rounds": 2}
//! ]}
//! ```
//!
//! Events fire at the **top** of their round, on the coordinator thread,
//! before any peer acts — so a `@3 join` peer takes its first turn in
//! round 3, and the schedule cannot perturb the bit-determinism contract
//! of the parallel pipeline (`tests/parallel_determinism.rs` pins a churn
//! scenario at 1 vs N threads).

use crate::chain::Uid;
use crate::minjson::{self, Value};
use crate::peers::Behavior;

/// One scripted population event.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A newcomer registers (slot rules apply: freed-uid reuse, eviction
    /// when the table is full) and starts contributing this round.
    JoinPeer { behavior: Behavior },
    /// The peer deregisters, freeing its uid and deleting its bucket.
    LeavePeer { uid: Uid },
    /// Set a neuron's stake to an absolute amount (0 demotes a validator).
    SetStake { uid: Uid, amount: f64 },
    /// Storage-provider degradation: PUTs fail with probability `prob`
    /// for `rounds` rounds, then the provider recovers.
    ProviderOutage { prob: f64, rounds: u64 },
    /// Read-path chaos window: GETs fail transiently with probability
    /// `prob` for `rounds` rounds (`@r chaos get-fail <p> [rounds]`).
    ChaosGetFail { prob: f64, rounds: u64 },
    /// Read-path chaos window: GET payloads arrive with one bit flipped
    /// with probability `prob` for `rounds` rounds — always rejected by
    /// the digest verdict (`@r chaos corrupt <p> [rounds]`).
    ChaosCorrupt { prob: f64, rounds: u64 },
    /// Targeted eclipse: `validator` cannot read `peer`'s bucket for
    /// `rounds` rounds (`@r eclipse <validator-uid> <peer-uid> [rounds]`).
    Eclipse { validator: Uid, peer: Uid, rounds: u64 },
}

/// A round-indexed event schedule. Events within a round fire in the
/// order they were written.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Scenario {
    events: Vec<(u64, Event)>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
#[error("scenario parse error: {0}")]
pub struct ScenarioError(pub String);

impl Scenario {
    pub fn new() -> Self {
        Scenario::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Schedule `event` at the top of `round`.
    pub fn at(mut self, round: u64, event: Event) -> Self {
        self.push(round, event);
        self
    }

    pub fn push(&mut self, round: u64, event: Event) {
        self.events.push((round, event));
    }

    /// All `(round, event)` pairs in authoring order.
    pub fn events(&self) -> impl Iterator<Item = &(u64, Event)> {
        self.events.iter()
    }

    /// Events scheduled for `round`, in authoring order.
    pub fn events_at(&self, round: u64) -> Vec<Event> {
        self.events.iter().filter(|(r, _)| *r == round).map(|(_, e)| e.clone()).collect()
    }

    /// The last round any event fires in (None when empty).
    pub fn last_round(&self) -> Option<u64> {
        self.events.iter().map(|(r, _)| *r).max()
    }

    /// Serialize the schedule as the documented JSON form, such that
    /// `Scenario::parse(&s.to_json().write())` reconstructs it exactly —
    /// run snapshots embed scenarios this way.
    pub fn to_json(&self) -> Value {
        let events: Vec<Value> = self
            .events
            .iter()
            .map(|(round, e)| {
                let mut fields: Vec<(&str, Value)> =
                    vec![("round", minjson::num(*round as f64))];
                match e {
                    Event::JoinPeer { behavior } => {
                        fields.push(("event", minjson::s("join")));
                        fields.push(("peer", minjson::s(&behavior.spec())));
                    }
                    Event::LeavePeer { uid } => {
                        fields.push(("event", minjson::s("leave")));
                        fields.push(("uid", minjson::num(*uid as f64)));
                    }
                    Event::SetStake { uid, amount } => {
                        fields.push(("event", minjson::s("stake")));
                        fields.push(("uid", minjson::num(*uid as f64)));
                        fields.push(("amount", minjson::num(*amount)));
                    }
                    Event::ProviderOutage { prob, rounds } => {
                        fields.push(("event", minjson::s("outage")));
                        fields.push(("prob", minjson::num(*prob)));
                        fields.push(("rounds", minjson::num(*rounds as f64)));
                    }
                    Event::ChaosGetFail { prob, rounds } => {
                        fields.push(("event", minjson::s("chaos-get-fail")));
                        fields.push(("prob", minjson::num(*prob)));
                        fields.push(("rounds", minjson::num(*rounds as f64)));
                    }
                    Event::ChaosCorrupt { prob, rounds } => {
                        fields.push(("event", minjson::s("chaos-corrupt")));
                        fields.push(("prob", minjson::num(*prob)));
                        fields.push(("rounds", minjson::num(*rounds as f64)));
                    }
                    Event::Eclipse { validator, peer, rounds } => {
                        fields.push(("event", minjson::s("eclipse")));
                        fields.push(("validator", minjson::num(*validator as f64)));
                        fields.push(("peer", minjson::num(*peer as f64)));
                        fields.push(("rounds", minjson::num(*rounds as f64)));
                    }
                }
                minjson::obj(fields)
            })
            .collect();
        minjson::obj(vec![("events", Value::Arr(events))])
    }

    /// Render the schedule in the documented compact one-event-per-line
    /// form, such that `Scenario::parse(&s.to_compact())` reconstructs it
    /// exactly. The scenario fuzzer prints failing scripts this way so
    /// they paste straight back into `gauntlet run --scenario`.
    pub fn to_compact(&self) -> String {
        self.events
            .iter()
            .map(|(round, e)| match e {
                Event::JoinPeer { behavior } => format!("@{round} join {}", behavior.spec()),
                Event::LeavePeer { uid } => format!("@{round} leave {uid}"),
                Event::SetStake { uid, amount } => format!("@{round} stake {uid} {amount}"),
                Event::ProviderOutage { prob, rounds } => {
                    format!("@{round} outage {prob} {rounds}")
                }
                Event::ChaosGetFail { prob, rounds } => {
                    format!("@{round} chaos get-fail {prob} {rounds}")
                }
                Event::ChaosCorrupt { prob, rounds } => {
                    format!("@{round} chaos corrupt {prob} {rounds}")
                }
                Event::Eclipse { validator, peer, rounds } => {
                    format!("@{round} eclipse {validator} {peer} {rounds}")
                }
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Parse either form (see module docs): JSON when the first non-space
    /// byte is `{` or `[`, compact text otherwise.
    pub fn parse(text: &str) -> Result<Scenario, ScenarioError> {
        let trimmed = text.trim_start();
        if trimmed.starts_with('{') || trimmed.starts_with('[') {
            Self::parse_json(text)
        } else {
            Self::parse_compact(text)
        }
    }

    fn parse_compact(text: &str) -> Result<Scenario, ScenarioError> {
        let mut out = Scenario::new();
        for raw in text.split(['\n', ';']) {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut toks = line.split_whitespace();
            let head = toks.next().unwrap();
            let round: u64 = head
                .strip_prefix('@')
                .ok_or_else(|| ScenarioError(format!("{line:?}: expected \"@<round> ...\"")))?
                .parse()
                .map_err(|e| ScenarioError(format!("{head:?}: bad round: {e}")))?;
            let verb = toks
                .next()
                .ok_or_else(|| ScenarioError(format!("{line:?}: missing event verb")))?;
            let args: Vec<&str> = toks.collect();
            let arg = |i: usize, what: &str| -> Result<&str, ScenarioError> {
                args.get(i)
                    .copied()
                    .ok_or_else(|| ScenarioError(format!("{line:?}: missing {what}")))
            };
            let event = match verb {
                "join" => Event::JoinPeer {
                    behavior: Behavior::parse_spec(arg(0, "behaviour spec")?)
                        .map_err(|e| ScenarioError(format!("{line:?}: {e}")))?,
                },
                "leave" => Event::LeavePeer {
                    uid: arg(0, "uid")?
                        .parse()
                        .map_err(|e| ScenarioError(format!("{line:?}: bad uid: {e}")))?,
                },
                "stake" => Event::SetStake {
                    uid: arg(0, "uid")?
                        .parse()
                        .map_err(|e| ScenarioError(format!("{line:?}: bad uid: {e}")))?,
                    amount: arg(1, "amount")?
                        .parse()
                        .map_err(|e| ScenarioError(format!("{line:?}: bad amount: {e}")))?,
                },
                "outage" => Event::ProviderOutage {
                    prob: arg(0, "probability")?
                        .parse()
                        .map_err(|e| ScenarioError(format!("{line:?}: bad prob: {e}")))?,
                    rounds: match args.get(1) {
                        None => 1,
                        Some(r) => r
                            .parse()
                            .map_err(|e| ScenarioError(format!("{line:?}: bad rounds: {e}")))?,
                    },
                },
                "chaos" => {
                    let kind = arg(0, "chaos kind (get-fail|corrupt)")?;
                    if kind != "get-fail" && kind != "corrupt" {
                        return Err(ScenarioError(format!(
                            "{line:?}: unknown chaos kind {kind:?}"
                        )));
                    }
                    let prob: f64 = arg(1, "probability")?
                        .parse()
                        .map_err(|e| ScenarioError(format!("{line:?}: bad prob: {e}")))?;
                    let rounds: u64 = match args.get(2) {
                        None => 1,
                        Some(r) => r
                            .parse()
                            .map_err(|e| ScenarioError(format!("{line:?}: bad rounds: {e}")))?,
                    };
                    if kind == "get-fail" {
                        Event::ChaosGetFail { prob, rounds }
                    } else {
                        Event::ChaosCorrupt { prob, rounds }
                    }
                }
                "eclipse" => Event::Eclipse {
                    validator: arg(0, "validator uid")?
                        .parse()
                        .map_err(|e| ScenarioError(format!("{line:?}: bad validator uid: {e}")))?,
                    peer: arg(1, "peer uid")?
                        .parse()
                        .map_err(|e| ScenarioError(format!("{line:?}: bad peer uid: {e}")))?,
                    rounds: match args.get(2) {
                        None => 1,
                        Some(r) => r
                            .parse()
                            .map_err(|e| ScenarioError(format!("{line:?}: bad rounds: {e}")))?,
                    },
                },
                other => {
                    return Err(ScenarioError(format!("{line:?}: unknown event {other:?}")))
                }
            };
            // Reject unconsumed tokens: a silently-dropped argument means
            // the run would execute a different schedule than authored.
            let used = match &event {
                Event::JoinPeer { .. } | Event::LeavePeer { .. } => 1,
                Event::SetStake { .. } => 2,
                Event::ProviderOutage { .. } => args.len().min(2),
                Event::ChaosGetFail { .. } | Event::ChaosCorrupt { .. } | Event::Eclipse { .. } => {
                    args.len().min(3)
                }
            };
            if args.len() > used {
                return Err(ScenarioError(format!(
                    "{line:?}: unexpected trailing tokens {:?}",
                    &args[used..]
                )));
            }
            out.push(round, event);
        }
        Ok(out)
    }

    fn parse_json(text: &str) -> Result<Scenario, ScenarioError> {
        fn jerr(i: usize, msg: impl std::fmt::Display) -> ScenarioError {
            ScenarioError(format!("event {i}: {msg}"))
        }
        fn juid(i: usize, e: &Value) -> Result<Uid, ScenarioError> {
            e.get("uid")
                .as_usize()
                .map(|u| u as Uid)
                .ok_or_else(|| jerr(i, "missing or bad \"uid\""))
        }
        let v = Value::parse(text).map_err(|e| ScenarioError(e.to_string()))?;
        // Accept both {"events": [...]} and a bare [...] array.
        let events = match (&v, v.get("events")) {
            (Value::Arr(a), _) => a.as_slice(),
            (_, Value::Arr(a)) => a.as_slice(),
            _ => return Err(ScenarioError("expected an array of events".into())),
        };
        let mut out = Scenario::new();
        for (i, e) in events.iter().enumerate() {
            let round = e
                .get("round")
                .as_f64()
                .filter(|r| *r >= 0.0 && r.fract() == 0.0)
                .ok_or_else(|| jerr(i, "missing or non-integer \"round\""))?
                as u64;
            let kind = e
                .get("event")
                .as_str()
                .ok_or_else(|| jerr(i, "missing \"event\" kind"))?;
            let event = match kind {
                "join" => Event::JoinPeer {
                    behavior: Behavior::parse_spec(
                        e.get("peer")
                            .as_str()
                            .ok_or_else(|| jerr(i, "missing \"peer\" behaviour spec"))?,
                    )
                    .map_err(|m| jerr(i, m))?,
                },
                "leave" => Event::LeavePeer { uid: juid(i, e)? },
                "stake" => Event::SetStake {
                    uid: juid(i, e)?,
                    amount: e
                        .get("amount")
                        .as_f64()
                        .ok_or_else(|| jerr(i, "missing \"amount\""))?,
                },
                "outage" => Event::ProviderOutage {
                    prob: e
                        .get("prob")
                        .as_f64()
                        .ok_or_else(|| jerr(i, "missing \"prob\""))?,
                    rounds: e.get("rounds").as_f64().map(|r| r as u64).unwrap_or(1),
                },
                "chaos-get-fail" => Event::ChaosGetFail {
                    prob: e
                        .get("prob")
                        .as_f64()
                        .ok_or_else(|| jerr(i, "missing \"prob\""))?,
                    rounds: e.get("rounds").as_f64().map(|r| r as u64).unwrap_or(1),
                },
                "chaos-corrupt" => Event::ChaosCorrupt {
                    prob: e
                        .get("prob")
                        .as_f64()
                        .ok_or_else(|| jerr(i, "missing \"prob\""))?,
                    rounds: e.get("rounds").as_f64().map(|r| r as u64).unwrap_or(1),
                },
                "eclipse" => Event::Eclipse {
                    validator: e
                        .get("validator")
                        .as_usize()
                        .map(|u| u as Uid)
                        .ok_or_else(|| jerr(i, "missing or bad \"validator\""))?,
                    peer: e
                        .get("peer")
                        .as_usize()
                        .map(|u| u as Uid)
                        .ok_or_else(|| jerr(i, "missing or bad \"peer\""))?,
                    rounds: e.get("rounds").as_f64().map(|r| r as u64).unwrap_or(1),
                },
                other => return Err(jerr(i, format!("unknown event kind {other:?}"))),
            };
            out.push(round, event);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_form_parses_every_event_kind() {
        let s = Scenario::parse(
            "# churn wave\n\
             @3 join honest:2\n\
             @3 join poisoner ; @5 leave 4\n\
             @6 stake 0 500\n\
             @7 outage 0.5 2\n\
             @8 outage 0.25   # default duration 1\n\
             @9 chaos get-fail 0.2 3\n\
             @9 chaos corrupt 0.05\n\
             @10 eclipse 0 5 2\n\
             @11 eclipse 1 6   # default duration 1\n",
        )
        .unwrap();
        assert_eq!(s.len(), 10);
        assert_eq!(
            s.events_at(9),
            vec![
                Event::ChaosGetFail { prob: 0.2, rounds: 3 },
                Event::ChaosCorrupt { prob: 0.05, rounds: 1 },
            ]
        );
        assert_eq!(s.events_at(10), vec![Event::Eclipse { validator: 0, peer: 5, rounds: 2 }]);
        assert_eq!(s.events_at(11), vec![Event::Eclipse { validator: 1, peer: 6, rounds: 1 }]);
        assert_eq!(
            s.events_at(3),
            vec![
                Event::JoinPeer { behavior: Behavior::Honest { data_mult: 2.0 } },
                Event::JoinPeer { behavior: Behavior::Poisoner { scale: 100.0 } },
            ]
        );
        assert_eq!(s.events_at(5), vec![Event::LeavePeer { uid: 4 }]);
        assert_eq!(s.events_at(6), vec![Event::SetStake { uid: 0, amount: 500.0 }]);
        assert_eq!(s.events_at(7), vec![Event::ProviderOutage { prob: 0.5, rounds: 2 }]);
        assert_eq!(s.events_at(8), vec![Event::ProviderOutage { prob: 0.25, rounds: 1 }]);
        assert_eq!(s.events_at(4), vec![]);
        assert_eq!(s.last_round(), Some(11));
    }

    #[test]
    fn json_form_matches_compact_form() {
        let compact = Scenario::parse("@3 join honest\n@5 leave 4\n@6 stake 0 500\n@7 outage 0.5 2")
            .unwrap();
        let json = Scenario::parse(
            r#"{"events": [
                {"round": 3, "event": "join", "peer": "honest"},
                {"round": 5, "event": "leave", "uid": 4},
                {"round": 6, "event": "stake", "uid": 0, "amount": 500},
                {"round": 7, "event": "outage", "prob": 0.5, "rounds": 2}
            ]}"#,
        )
        .unwrap();
        assert_eq!(compact, json);
        let chaos_compact = Scenario::parse(
            "@2 chaos get-fail 0.25 3\n@2 chaos corrupt 0.125\n@4 eclipse 0 5 2",
        )
        .unwrap();
        let chaos_json = Scenario::parse(
            r#"{"events": [
                {"round": 2, "event": "chaos-get-fail", "prob": 0.25, "rounds": 3},
                {"round": 2, "event": "chaos-corrupt", "prob": 0.125},
                {"round": 4, "event": "eclipse", "validator": 0, "peer": 5, "rounds": 2}
            ]}"#,
        )
        .unwrap();
        assert_eq!(chaos_compact, chaos_json);
        // bare-array form is accepted too
        let bare = Scenario::parse(r#"[{"round": 3, "event": "join", "peer": "honest"}]"#).unwrap();
        assert_eq!(bare.events_at(3).len(), 1);
    }

    #[test]
    fn to_json_roundtrips_through_parse() {
        let s = Scenario::parse(
            "@3 join honest:2\n@3 join desync:4:2\n@5 leave 4\n\
             @6 stake 0 512.5\n@7 outage 0.5 2",
        )
        .unwrap();
        let back = Scenario::parse(&s.to_json().write()).unwrap();
        assert_eq!(s, back);
        assert_eq!(Scenario::parse(&Scenario::default().to_json().write()).unwrap().len(), 0);
    }

    #[test]
    fn to_compact_roundtrips_through_parse() {
        let s = Scenario::parse(
            "@3 join honest:2\n@3 join sybil:7:0.25\n@5 leave 4\n\
             @6 stake 0 512.5\n@7 outage 0.5 2\n@9 join stale:3",
        )
        .unwrap();
        assert_eq!(Scenario::parse(&s.to_compact()).unwrap(), s);
        assert_eq!(Scenario::default().to_compact(), "");
    }

    #[test]
    fn random_scenarios_roundtrip_compact_and_json() {
        crate::prop::check("scenario-grammar-roundtrip", 48, |rng, size| {
            let s = crate::prop::scenario::arbitrary_scenario(rng, size);
            let compact = Scenario::parse(&s.to_compact())
                .map_err(|e| format!("compact parse failed: {e}\n{}", s.to_compact()))?;
            crate::prop_assert!(compact == s, "compact roundtrip drifted:\n{}", s.to_compact());
            let json = Scenario::parse(&s.to_json().write())
                .map_err(|e| format!("json parse failed: {e}\n{}", s.to_json().write()))?;
            crate::prop_assert!(json == s, "json roundtrip drifted:\n{}", s.to_json().write());
            Ok(())
        });
    }

    #[test]
    fn parse_errors_are_descriptive() {
        for (bad, needle) in [
            ("3 join honest", "@<round>"),
            ("@x join honest", "bad round"),
            ("@3", "missing event verb"),
            ("@3 dance", "unknown event"),
            ("@3 join gremlin", "unknown peer behaviour"),
            ("@3 leave", "missing uid"),
            ("@3 leave 4 5", "unexpected trailing tokens"),
            ("@3 stake 4", "missing amount"),
            ("@3 stake 4 10 20", "unexpected trailing tokens"),
            ("@3 outage", "missing probability"),
            ("@3 outage 0.5 2 9", "unexpected trailing tokens"),
            ("@3 chaos", "missing chaos kind"),
            ("@3 chaos warp 0.5", "unknown chaos kind"),
            ("@3 chaos get-fail", "missing probability"),
            ("@3 chaos corrupt 0.1 2 9", "unexpected trailing tokens"),
            ("@3 eclipse", "missing validator uid"),
            ("@3 eclipse 0", "missing peer uid"),
            ("@3 eclipse 0 5 2 9", "unexpected trailing tokens"),
        ] {
            let err = Scenario::parse(bad).unwrap_err();
            assert!(err.0.contains(needle), "{bad:?} -> {err}");
        }
        assert!(Scenario::parse(r#"{"events": [{"event": "join"}]}"#).is_err());
        assert!(Scenario::parse(r#"{"events": 7}"#).is_err());
    }

    #[test]
    fn empty_and_comment_only_scripts_are_empty() {
        assert!(Scenario::parse("").unwrap().is_empty());
        assert!(Scenario::parse("\n  # nothing here\n;;\n").unwrap().is_empty());
        assert_eq!(Scenario::default().last_round(), None);
    }

    #[test]
    fn builder_api_orders_within_a_round() {
        let s = Scenario::new()
            .at(2, Event::LeavePeer { uid: 1 })
            .at(2, Event::JoinPeer { behavior: Behavior::Freeloader });
        assert_eq!(
            s.events_at(2),
            vec![
                Event::LeavePeer { uid: 1 },
                Event::JoinPeer { behavior: Behavior::Freeloader },
            ]
        );
    }
}
