//! `detlint` CLI — scan one or more paths, print `file:line: RULE message`
//! diagnostics, exit non-zero if any finding survives.
//!
//! ```text
//! detlint [--list-rules] [--quiet] <path>...
//! ```
//!
//! Paths may be directories (scanned recursively for `.rs` files, in
//! sorted order) or single files. With no path, scans `rust/src` if it
//! exists under the current directory, else errors.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut quiet = false;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--list-rules" => {
                for (id, what) in detlint::RULES {
                    println!("{id}  {what}");
                }
                return ExitCode::SUCCESS;
            }
            "--quiet" | "-q" => quiet = true,
            "--help" | "-h" => {
                println!("usage: detlint [--list-rules] [--quiet] <path>...");
                println!("scans .rs trees for determinism/unsafety violations; exits 1 on findings");
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                eprintln!("detlint: unknown flag {other:?} (try --help)");
                return ExitCode::from(2);
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    if paths.is_empty() {
        let default = Path::new("rust/src");
        if default.is_dir() {
            paths.push(default.to_path_buf());
        } else {
            eprintln!("detlint: no path given and ./rust/src not found (try --help)");
            return ExitCode::from(2);
        }
    }

    let mut total_findings = 0usize;
    let mut total_files = 0usize;
    let mut total_allows = 0usize;
    for path in &paths {
        match detlint::scan_tree(path) {
            Ok(report) => {
                for f in &report.findings {
                    println!("{f}");
                }
                total_findings += report.findings.len();
                total_files += report.files;
                total_allows += report.allows_used;
            }
            Err(e) => {
                eprintln!("detlint: {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
    }
    if !quiet {
        eprintln!(
            "detlint: {total_files} file(s), {total_findings} finding(s), \
             {total_allows} allow(s) in effect"
        );
    }
    if total_findings == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
