//! detlint — Gauntlet's in-tree determinism & unsafety linter.
//!
//! Every validator in the Gauntlet incentive pipeline must reproduce
//! **bit-identical** scores: the paper's two-stage filtering and
//! loss-delta attribution collapse if summation order, map iteration
//! order, or a wall-clock branch makes two honest validators disagree.
//! That contract is enforced dynamically by the 1-vs-N-thread fingerprint
//! tests; this crate enforces it *statically*, so the next PR cannot
//! quietly introduce a `HashMap` iteration or an `Instant::now()` branch
//! into the round path.
//!
//! The scanner is hand-rolled (no syn, no rustc plumbing, no
//! dependencies, in the same spirit as the crate's `minjson`): a
//! comment/string-stripping pass, a line/token scanner, and a handful of
//! context trackers (brace depth, enclosing `fn`, `#[cfg(test)]`
//! regions). It trades full type resolution for auditability — the
//! heuristics and their blind spots are documented on each rule.
//!
//! # Module classification
//!
//! Files are classified by their top-level module (first path component
//! under the scan root):
//!
//! - **edge** — `bench`, `main.rs`, `prop`: measurement, CLI, and fuzz
//!   harness code that legitimately reads clocks and environment.
//! - **round-path** — everything else (`chain`, `coordinator`, `demo`,
//!   `eval`, `openskill`, `peers`, `runtime`, `storage`, `scenario`,
//!   `data`, `util`, `minjson`, `lib.rs`, and any *new* module until it
//!   is explicitly classified): code that can influence a round's
//!   scores, weights, or artifacts. Unknown modules default to
//!   round-path on purpose — a new subsystem must opt *out* of the
//!   determinism contract, never silently fall outside it.
//!
//! `#[cfg(test)]` (and `#[cfg(loom)]`) items are skipped entirely: tests
//! assert on round-path behaviour but do not produce it.
//!
//! # Rules
//!
//! | rule | fires on (round-path unless noted) |
//! |------|------------------------------------|
//! | D001 | iteration over a `HashMap`/`HashSet` binding (`.iter()`, `.keys()`, `.values()`, `.drain()`, `.retain()`, `for .. in map`, ...). Keyed lookup (`get`/`insert`/`contains_key`) is fine; iteration must use ordered structures (`BTreeMap`) or sort first. |
//! | D002 | wall-clock / entropy / environment reads (`Instant::now`, `SystemTime::now`, `env::var`, `env::var_os`, `env::args`, `env::temp_dir`, `thread_rng`, `from_entropy`) anywhere outside edge modules and the single blessed `effective_threads()` resolution site. |
//! | D003 | bare float reductions: `.sum::<f32/f64>()`, `.sum()` in a statement mentioning `f32`/`f64`, and `.fold(<float literal>, ..)` with an additive/unknown combiner (pure `min`/`max` folds are order-insensitive and exempt). Reductions must go through the `lane_reduce` kernels, `util::det_sum`, or carry a per-site allow with a determinism argument. |
//! | U001 | an `unsafe` block/fn/impl (any module) whose statement is not preceded by a `// SAFETY:` comment or a `# Safety` doc section. |
//!
//! # Allow grammar
//!
//! A finding is suppressed by a comment on the same line, or in the
//! comment block immediately above the flagged statement:
//!
//! ```text
//! // detlint: allow(D002, resolved once at backend construction, never per round)
//! ```
//!
//! The reason is mandatory — an allow without one is itself reported
//! (rule `ALLOW`). The reason should state *why the site is still
//! deterministic* (or why nondeterminism cannot reach round state), not
//! merely that the author wanted the lint gone.
//!
//! # Known blind spots (by design of a token-level scanner)
//!
//! - D001 tracks bindings declared in the same file (`let m: HashMap<..>`,
//!   struct fields, fn params). A map smuggled through a type alias or a
//!   cross-file getter is not seen.
//! - D003 does not see open-coded `for`-loop float accumulation; those
//!   are in-order by construction, which is exactly the property the
//!   rule forces `.sum()` call sites to make explicit.
//! - `#[cfg(test)] mod tests;` (out-of-line test module) would be
//!   scanned as regular code; the workspace keeps test modules inline.

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// All rule identifiers, in severity-agnostic display order.
pub const RULES: &[(&str, &str)] = &[
    ("D001", "no HashMap/HashSet iteration in round-path modules"),
    ("D002", "no wall-clock/entropy/env reads outside edge modules"),
    ("D003", "no bare float .sum()/.fold() reductions in round-path modules"),
    ("U001", "every `unsafe` must carry a SAFETY justification"),
    ("ALLOW", "malformed `detlint: allow(..)` directive"),
];

/// Whether a module may read clocks/entropy/environment and is exempt
/// from the determinism rules (D001–D003). U001 applies everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleClass {
    /// Code that can influence a round's scores, weights, or artifacts.
    RoundPath,
    /// Measurement / CLI / fuzz-harness code (`bench`, `main.rs`, `prop`).
    Edge,
}

/// Classify a path *relative to the scan root* (e.g. `chain/yuma.rs`).
pub fn classify(rel: &str) -> ModuleClass {
    let top = rel.split('/').next().unwrap_or(rel);
    let name = top.strip_suffix(".rs").unwrap_or(top);
    match name {
        "bench" | "main" | "prop" => ModuleClass::Edge,
        _ => ModuleClass::RoundPath,
    }
}

/// One diagnostic, with a stable `file:line` anchor.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier (`D001`..`U001`, `ALLOW`).
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// Aggregate result of a tree scan.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Number of findings suppressed by a valid allow directive.
    pub allows_used: usize,
}

// ---------------------------------------------------------------------
// Pass 1: strip comments and literals.
// ---------------------------------------------------------------------

/// Source text split into per-line *code* (comments and literal contents
/// blanked) and per-line *comment text* (line, block, and doc comments).
struct Stripped {
    code: Vec<String>,
    comments: Vec<String>,
}

struct StripState {
    code: Vec<String>,
    comments: Vec<String>,
    line: usize,
}

impl StripState {
    fn new() -> StripState {
        StripState { code: vec![String::new()], comments: vec![String::new()], line: 0 }
    }
    fn newline(&mut self) {
        self.code.push(String::new());
        self.comments.push(String::new());
        self.line += 1;
    }
    fn code_push(&mut self, c: char) {
        self.code[self.line].push(c);
    }
    fn comment_push(&mut self, c: char) {
        self.comments[self.line].push(c);
    }
}

fn strip(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut st = StripState::new();
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            st.newline();
            i += 1;
        } else if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            i += 2;
            while i < n && chars[i] != '\n' {
                st.comment_push(chars[i]);
                i += 1;
            }
        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            // Block comment; Rust block comments nest.
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    st.newline();
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    st.comment_push(chars[i]);
                    i += 1;
                }
            }
        } else if c == '"' {
            st.code_push(' ');
            i += 1;
            skip_escaped_string(&chars, &mut i, &mut st);
        } else if c == '\'' {
            // Char literal vs lifetime. A `'` followed by a backslash is
            // always a char escape; `'x'` (closing quote two ahead) is a
            // plain char literal; anything else (`'env`, `'static`) is a
            // lifetime and stays in the code stream.
            if i + 1 < n && chars[i + 1] == '\\' {
                i += 2;
                while i < n && chars[i] != '\'' {
                    if chars[i] == '\n' {
                        st.newline();
                    }
                    i += 1;
                }
                i += 1;
                st.code_push(' ');
            } else if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                i += 3;
                st.code_push(' ');
            } else {
                st.code_push('\'');
                i += 1;
            }
        } else if c.is_alphabetic() || c == '_' {
            // Consume the identifier, then check for raw/byte string
            // heads (`r"..."`, `r#"..."#`, `br"..."`, `b"..."`).
            let mut ident = String::new();
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                ident.push(chars[i]);
                i += 1;
            }
            let raw = ident == "r" || ident == "br";
            let byte = ident == "b";
            if raw && i < n && (chars[i] == '"' || chars[i] == '#') {
                let mut hashes = 0usize;
                let mut j = i;
                while j < n && chars[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && chars[j] == '"' {
                    // Raw string: no escapes; terminated by `"` + hashes.
                    i = j + 1;
                    st.code_push(' ');
                    while i < n {
                        if chars[i] == '\n' {
                            st.newline();
                            i += 1;
                        } else if chars[i] == '"' {
                            let mut k = i + 1;
                            let mut h = 0usize;
                            while k < n && h < hashes && chars[k] == '#' {
                                h += 1;
                                k += 1;
                            }
                            i = k;
                            if h == hashes {
                                break;
                            }
                        } else {
                            i += 1;
                        }
                    }
                    continue;
                }
                // `r#ident` (raw identifier): fall through, emit as code.
            }
            if byte && i < n && chars[i] == '"' {
                i += 1;
                st.code_push(' ');
                skip_escaped_string(&chars, &mut i, &mut st);
                continue;
            }
            for c in ident.chars() {
                st.code_push(c);
            }
        } else {
            st.code_push(c);
            i += 1;
        }
    }
    Stripped { code: st.code, comments: st.comments }
}

/// Consume an escape-aware string body; `*i` points just past the opening
/// quote on entry and just past the closing quote on exit.
fn skip_escaped_string(chars: &[char], i: &mut usize, st: &mut StripState) {
    while *i < chars.len() {
        match chars[*i] {
            '\\' => *i += 2,
            '"' => {
                *i += 1;
                return;
            }
            '\n' => {
                st.newline();
                *i += 1;
            }
            _ => *i += 1,
        }
    }
}

// ---------------------------------------------------------------------
// Pass 2: tokenize the stripped code.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum TokKind {
    Ident(String),
    Num(String),
    Punct(char),
}

#[derive(Debug, Clone)]
struct Tok {
    kind: TokKind,
    /// 0-based source line.
    line: usize,
}

fn tokenize(code: &[String]) -> Vec<Tok> {
    let mut toks = Vec::new();
    for (line, text) in code.iter().enumerate() {
        let chars: Vec<char> = text.chars().collect();
        let n = chars.len();
        let mut i = 0;
        while i < n {
            let c = chars[i];
            if c.is_whitespace() {
                i += 1;
            } else if c.is_alphabetic() || c == '_' {
                let mut s = String::new();
                while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    s.push(chars[i]);
                    i += 1;
                }
                toks.push(Tok { kind: TokKind::Ident(s), line });
            } else if c.is_ascii_digit() {
                // Number: integer part, optional `.digits` fraction (but
                // not `0..n` ranges), then any suffix/exponent run
                // (`_f64`, `e10`, `u64`, ...).
                let mut s = String::new();
                while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                    s.push(chars[i]);
                    i += 1;
                }
                if i + 1 < n && chars[i] == '.' && chars[i + 1].is_ascii_digit() {
                    s.push('.');
                    i += 1;
                    while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                        s.push(chars[i]);
                        i += 1;
                    }
                }
                while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    s.push(chars[i]);
                    i += 1;
                    // Exponent sign: `1e-3`.
                    if (s.ends_with('e') || s.ends_with('E'))
                        && i < n
                        && (chars[i] == '+' || chars[i] == '-')
                        && i + 1 < n
                        && chars[i + 1].is_ascii_digit()
                    {
                        s.push(chars[i]);
                        i += 1;
                    }
                }
                toks.push(Tok { kind: TokKind::Num(s), line });
            } else {
                toks.push(Tok { kind: TokKind::Punct(c), line });
                i += 1;
            }
        }
    }
    toks
}

fn ident(toks: &[Tok], i: usize) -> Option<&str> {
    match toks.get(i).map(|t| &t.kind) {
        Some(TokKind::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct(toks: &[Tok], i: usize, c: char) -> bool {
    matches!(toks.get(i).map(|t| &t.kind), Some(TokKind::Punct(p)) if *p == c)
}

// ---------------------------------------------------------------------
// Pass 3: context — statement starts, cfg(test) regions, enclosing fns.
// ---------------------------------------------------------------------

struct Context {
    /// For each token: index of the first token of its statement
    /// (statements are delimited by `;`, `{`, `}`).
    stmt_start: Vec<usize>,
    /// For each token: inside a `#[cfg(test)]` / `#[cfg(loom)]` item.
    skipped: Vec<bool>,
    /// For each token: inside the blessed `fn effective_threads`.
    blessed_env_fn: Vec<bool>,
}

fn build_context(toks: &[Tok]) -> Context {
    let n = toks.len();
    let mut stmt_start = vec![0usize; n];
    let mut skipped = vec![false; n];
    let mut blessed = vec![false; n];

    let mut start = 0usize;
    let mut depth = 0usize;
    // Stack of depths at which a skip region opened.
    let mut skip_open: Vec<usize> = Vec::new();
    let mut pending_skip = false;
    // Stack of (depth, fn name) for enclosing named fns.
    let mut fn_stack: Vec<(usize, String)> = Vec::new();
    let mut pending_fn: Option<String> = None;

    let mut i = 0;
    while i < n {
        stmt_start[i] = start;
        skipped[i] = !skip_open.is_empty();
        blessed[i] = fn_stack.iter().any(|(_, name)| name == "effective_threads");

        match &toks[i].kind {
            TokKind::Punct('#') if punct(toks, i + 1, '[') => {
                // Attribute: scan to the matching `]`, look for a cfg
                // gated on `test`/`loom` (but not `not(test)`).
                let mut j = i + 2;
                let mut bdepth = 1usize;
                let attr_start = j;
                while j < n && bdepth > 0 {
                    match toks[j].kind {
                        TokKind::Punct('[') => bdepth += 1,
                        TokKind::Punct(']') => bdepth -= 1,
                        _ => {}
                    }
                    j += 1;
                }
                let attr = &toks[attr_start..j.saturating_sub(1).max(attr_start)];
                let is_cfg =
                    attr.first().is_some_and(|t| matches!(&t.kind, TokKind::Ident(s) if s == "cfg"));
                if is_cfg {
                    let mut k = 0;
                    while k < attr.len() {
                        if let TokKind::Ident(name) = &attr[k].kind {
                            if (name == "test" || name == "loom")
                                && !(k >= 2
                                    && matches!(&attr[k - 2].kind, TokKind::Ident(p) if p == "not")
                                    && matches!(attr[k - 1].kind, TokKind::Punct('(')))
                            {
                                pending_skip = true;
                            }
                        }
                        k += 1;
                    }
                }
                // Mark the attribute's own tokens and move past it.
                while i < j {
                    stmt_start[i] = start;
                    skipped[i] = !skip_open.is_empty();
                    blessed[i] =
                        fn_stack.iter().any(|(_, name)| name == "effective_threads");
                    i += 1;
                }
                continue;
            }
            TokKind::Ident(s) if s == "fn" => {
                if let Some(name) = ident(toks, i + 1) {
                    pending_fn = Some(name.to_string());
                }
            }
            TokKind::Punct('{') => {
                if pending_skip {
                    skip_open.push(depth);
                    pending_skip = false;
                    // The brace itself belongs to the skipped item.
                    skipped[i] = true;
                }
                if let Some(name) = pending_fn.take() {
                    fn_stack.push((depth, name));
                }
                depth += 1;
                start = i + 1;
            }
            TokKind::Punct('}') => {
                depth = depth.saturating_sub(1);
                if skip_open.last() == Some(&depth) {
                    skip_open.pop();
                }
                if fn_stack.last().map(|(d, _)| *d) == Some(depth) {
                    fn_stack.pop();
                }
                start = i + 1;
            }
            TokKind::Punct(';') => {
                // An item ended before any body: cancel pending markers
                // (`#[cfg(test)] mod tests;`, trait fn declarations).
                pending_skip = false;
                pending_fn = None;
                start = i + 1;
            }
            _ => {}
        }
        i += 1;
    }
    Context { stmt_start, skipped, blessed_env_fn: blessed }
}

// ---------------------------------------------------------------------
// Allow directives.
// ---------------------------------------------------------------------

/// Valid allows per 0-based line: rule names suppressible on that line.
struct Allows {
    by_line: Vec<Vec<String>>,
}

fn parse_allows(rel: &str, comments: &[String], findings: &mut Vec<Finding>) -> Allows {
    let mut by_line: Vec<Vec<String>> = vec![Vec::new(); comments.len()];
    for (line, text) in comments.iter().enumerate() {
        let mut rest = text.as_str();
        while let Some(pos) = rest.find("detlint:") {
            rest = &rest[pos + "detlint:".len()..];
            let body = rest.trim_start();
            let Some(args) = body.strip_prefix("allow").map(|b| b.trim_start()) else {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: line + 1,
                    rule: "ALLOW",
                    message: "malformed directive: expected `detlint: allow(RULE, reason)`"
                        .to_string(),
                });
                continue;
            };
            let Some(inner) = args.strip_prefix('(').and_then(|a| a.split_once(')')) else {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: line + 1,
                    rule: "ALLOW",
                    message: "malformed directive: missing `(RULE, reason)`".to_string(),
                });
                continue;
            };
            let (inside, _after) = inner;
            let (rule, reason) = match inside.split_once(',') {
                Some((r, why)) => (r.trim(), why.trim()),
                None => (inside.trim(), ""),
            };
            if !RULES.iter().any(|(id, _)| *id == rule && *id != "ALLOW") {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: line + 1,
                    rule: "ALLOW",
                    message: format!("unknown rule {rule:?} in allow directive"),
                });
            } else if reason.is_empty() {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: line + 1,
                    rule: "ALLOW",
                    message: format!(
                        "allow({rule}) needs a reason: `detlint: allow({rule}, why this \
                         site stays deterministic)`"
                    ),
                });
            } else {
                by_line[line].push(rule.to_string());
            }
        }
    }
    Allows { by_line }
}

impl Allows {
    /// A finding on `line` (0-based), whose statement starts on
    /// `stmt_line`, is suppressed by an allow on the finding line itself,
    /// or anywhere in the contiguous comment/blank block directly above
    /// the finding line or the statement start line.
    fn covers(&self, code: &[String], rule: &str, line: usize, stmt_line: usize) -> bool {
        let has = |l: usize| self.by_line.get(l).is_some_and(|v| v.iter().any(|r| r == rule));
        if has(line) {
            return true;
        }
        for anchor in [line, stmt_line] {
            let mut l = anchor;
            while l > 0 {
                l -= 1;
                if !code[l].trim().is_empty() {
                    break;
                }
                if has(l) {
                    return true;
                }
            }
        }
        false
    }
}

// ---------------------------------------------------------------------
// Rules.
// ---------------------------------------------------------------------

const D001_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

const D002_PATTERNS: &[(&[&str], &str)] = &[
    (&["Instant", ":", ":", "now"], "wall-clock read (Instant::now)"),
    (&["SystemTime", ":", ":", "now"], "wall-clock read (SystemTime::now)"),
    (&["env", ":", ":", "var"], "environment read (env::var)"),
    (&["env", ":", ":", "var_os"], "environment read (env::var_os)"),
    (&["env", ":", ":", "args"], "process-argument read (env::args)"),
    (&["env", ":", ":", "args_os"], "process-argument read (env::args_os)"),
    (&["env", ":", ":", "temp_dir"], "environment read (env::temp_dir)"),
    (&["thread_rng"], "OS entropy (thread_rng)"),
    (&["from_entropy"], "OS entropy (from_entropy)"),
];

/// Match an ident/punct pattern (`":"` entries are `:` puncts) at `i`.
fn match_pattern(toks: &[Tok], i: usize, pat: &[&str]) -> bool {
    for (off, want) in pat.iter().enumerate() {
        let ok = match toks.get(i + off).map(|t| &t.kind) {
            Some(TokKind::Ident(s)) => s == want,
            Some(TokKind::Punct(c)) => want.len() == 1 && want.chars().next() == Some(*c),
            _ => false,
        };
        if !ok {
            return false;
        }
    }
    true
}

/// Collect identifiers bound to a `HashMap`/`HashSet` in this file:
/// `let m: HashMap<..>`, `m: HashMap<..>` struct fields / fn params, and
/// `let m = HashMap::new()` / `HashMap::from(..)` / `with_capacity`.
fn collect_hash_bindings(toks: &[Tok], ctx: &Context) -> Vec<String> {
    let mut names = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if ctx.skipped[i] {
            i += 1;
            continue;
        }
        let is_hash = matches!(ident(toks, i), Some("HashMap") | Some("HashSet"));
        if is_hash {
            let start = ctx.stmt_start[i];
            // `name : HashMap` (possibly through `&`, `&mut`): annotation.
            let mut j = i;
            while j > start && (punct(toks, j - 1, '&') || ident(toks, j - 1) == Some("mut")) {
                j -= 1;
            }
            if j >= 2 && punct(toks, j - 1, ':') && !punct(toks, j - 2, ':') {
                if let Some(name) = ident(toks, j - 2) {
                    names.push(name.to_string());
                    i += 1;
                    continue;
                }
            }
            // `let name = HashMap::...` / `let mut name = HashMap::...`.
            let mut k = i;
            while k > start {
                k -= 1;
                if ident(toks, k) == Some("let") {
                    let mut m = k + 1;
                    if ident(toks, m) == Some("mut") {
                        m += 1;
                    }
                    if let Some(name) = ident(toks, m) {
                        if punct(toks, m + 1, '=') {
                            names.push(name.to_string());
                        }
                    }
                    break;
                }
            }
        }
        i += 1;
    }
    names.sort();
    names.dedup();
    names
}

struct FileScan<'a> {
    rel: &'a str,
    class: ModuleClass,
    toks: Vec<Tok>,
    ctx: Context,
    stripped: Stripped,
}

impl FileScan<'_> {
    fn finding(&self, i: usize, rule: &'static str, message: String) -> Finding {
        Finding { file: self.rel.to_string(), line: self.toks[i].line + 1, rule, message }
    }

    /// The statement's float reduction context: does any token of the
    /// current statement before `i` name `f32`/`f64`?
    fn stmt_mentions_float(&self, i: usize) -> bool {
        let start = self.ctx.stmt_start[i];
        (start..i).any(|k| matches!(ident(&self.toks, k), Some("f32") | Some("f64")))
    }

    fn d001(&self, out: &mut Vec<Finding>) {
        if self.class != ModuleClass::RoundPath {
            return;
        }
        let bindings = collect_hash_bindings(&self.toks, &self.ctx);
        if bindings.is_empty() {
            return;
        }
        let toks = &self.toks;
        let mut in_for_header = false;
        let mut i = 0;
        while i < toks.len() {
            if self.ctx.skipped[i] {
                i += 1;
                continue;
            }
            match &toks[i].kind {
                TokKind::Ident(s) if s == "for" => {
                    // `impl Trait for Type` headers contain no hash
                    // bindings (type names, not locals), so a single
                    // header mode is enough.
                    in_for_header = true;
                }
                TokKind::Punct('{') | TokKind::Punct(';') => in_for_header = false,
                TokKind::Ident(name) if bindings.iter().any(|b| b == name) => {
                    if punct(toks, i + 1, '.') {
                        if let Some(m) = ident(toks, i + 2) {
                            if D001_ITER_METHODS.contains(&m) && punct(toks, i + 3, '(') {
                                out.push(self.finding(
                                    i,
                                    "D001",
                                    format!(
                                        "iteration over hash-ordered `{name}.{m}()`; round-path \
                                         iteration must use an ordered structure (BTreeMap/\
                                         BTreeSet, indexed Vec) or sort first"
                                    ),
                                ));
                            }
                        }
                    } else if in_for_header && punct(toks, i + 1, '{') {
                        out.push(self.finding(
                            i,
                            "D001",
                            format!(
                                "`for .. in {name}` iterates a hash-ordered container; \
                                 round-path iteration must use an ordered structure"
                            ),
                        ));
                    }
                }
                _ => {}
            }
            i += 1;
        }
    }

    fn d002(&self, out: &mut Vec<Finding>) {
        if self.class != ModuleClass::RoundPath {
            return;
        }
        let mut i = 0;
        while i < self.toks.len() {
            if self.ctx.skipped[i] || self.ctx.blessed_env_fn[i] {
                i += 1;
                continue;
            }
            for (pat, what) in D002_PATTERNS {
                if match_pattern(&self.toks, i, pat) {
                    out.push(self.finding(
                        i,
                        "D002",
                        format!(
                            "{what} in a round-path module; resolve once at assembly \
                             (see RunConfig::effective_threads) or move to an edge module"
                        ),
                    ));
                    i += pat.len() - 1;
                    break;
                }
            }
            i += 1;
        }
    }

    fn d003(&self, out: &mut Vec<Finding>) {
        if self.class != ModuleClass::RoundPath {
            return;
        }
        let toks = &self.toks;
        let mut i = 0;
        while i < toks.len() {
            if self.ctx.skipped[i] || !punct(toks, i, '.') {
                i += 1;
                continue;
            }
            match ident(toks, i + 1) {
                Some("sum") => {
                    let turbo_float = punct(toks, i + 2, ':')
                        && punct(toks, i + 3, ':')
                        && punct(toks, i + 4, '<')
                        && matches!(ident(toks, i + 5), Some("f32") | Some("f64"));
                    let bare = punct(toks, i + 2, '(');
                    if turbo_float || (bare && self.stmt_mentions_float(i)) {
                        out.push(self.finding(
                            i + 1,
                            "D003",
                            "bare float `.sum()`; use the lane_reduce kernels or \
                             util::det_sum (strictly in-order), or add an allow with a \
                             determinism argument"
                                .to_string(),
                        ));
                    }
                }
                Some("fold") if punct(toks, i + 2, '(') => {
                    if let Some(f) = self.check_fold(i) {
                        out.push(f);
                    }
                }
                _ => {}
            }
            i += 1;
        }
    }

    /// `.fold(<float literal>, combiner)`: flag unless the combiner is a
    /// pure `min`/`max` (order-insensitive up to NaN placement, which the
    /// callers pin separately).
    fn check_fold(&self, dot: usize) -> Option<Finding> {
        let toks = &self.toks;
        let open = dot + 2;
        let mut depth = 1usize;
        let mut j = open + 1;
        while j < toks.len() && depth > 0 {
            match toks[j].kind {
                TokKind::Punct('(') => depth += 1,
                TokKind::Punct(')') => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        let close = j - 1;
        // Split the argument list at the first top-level comma.
        let mut depth = 0usize;
        let mut comma = None;
        for k in open + 1..close {
            match toks[k].kind {
                TokKind::Punct('(') | TokKind::Punct('[') | TokKind::Punct('{') => depth += 1,
                TokKind::Punct(')') | TokKind::Punct(']') | TokKind::Punct('}') => {
                    depth = depth.saturating_sub(1)
                }
                TokKind::Punct(',') if depth == 0 => {
                    comma = Some(k);
                    break;
                }
                _ => {}
            }
        }
        let comma = comma?;
        // Seed: a float literal (skip a leading unary minus)?
        let mut s = open + 1;
        if punct(toks, s, '-') {
            s += 1;
        }
        let seed_is_float = match toks.get(s).map(|t| &t.kind) {
            Some(TokKind::Num(num)) if s + 1 == comma => {
                num.contains('.') || num.ends_with("f32") || num.ends_with("f64")
            }
            _ => false,
        };
        if !seed_is_float {
            return None;
        }
        let combiner = &toks[comma + 1..close];
        let has = |pred: &dyn Fn(&TokKind) -> bool| combiner.iter().any(|t| pred(&t.kind));
        let additive = has(&|k| {
            matches!(k, TokKind::Punct('+') | TokKind::Punct('*'))
                || matches!(k, TokKind::Ident(s) if s == "mul_add" || s == "sum")
        });
        let minmax = has(&|k| matches!(k, TokKind::Ident(s) if s == "max" || s == "min"));
        if !additive && minmax {
            return None;
        }
        Some(self.finding(
            dot + 1,
            "D003",
            "float fold-accumulation; use the lane_reduce kernels or util::det_sum \
             (strictly in-order), or add an allow with a determinism argument"
                .to_string(),
        ))
    }

    fn u001(&self, out: &mut Vec<Finding>) {
        let toks = &self.toks;
        let mut i = 0;
        while i < toks.len() {
            if !self.ctx.skipped[i] && ident(toks, i) == Some("unsafe") {
                let line = toks[i].line;
                let stmt_line = toks[self.ctx.stmt_start[i]].line;
                if !self.has_safety_comment(stmt_line, line) {
                    out.push(self.finding(
                        i,
                        "U001",
                        "`unsafe` without a justification; precede the statement with a \
                         `// SAFETY:` comment (or a `# Safety` doc section) stating the \
                         discharged obligations"
                            .to_string(),
                    ));
                }
            }
            i += 1;
        }
    }

    /// A SAFETY justification covers an `unsafe` on `line` if it appears
    /// in a comment on any line of the statement (`stmt_line..=line`) or
    /// in the contiguous comment/blank block directly above the statement.
    fn has_safety_comment(&self, stmt_line: usize, line: usize) -> bool {
        let marker = |l: usize| {
            self.stripped
                .comments
                .get(l)
                .is_some_and(|c| c.contains("SAFETY") || c.contains("# Safety"))
        };
        if (stmt_line..=line).any(marker) {
            return true;
        }
        let mut l = stmt_line;
        while l > 0 {
            l -= 1;
            if !self.stripped.code[l].trim().is_empty() {
                return false;
            }
            if marker(l) {
                return true;
            }
        }
        false
    }
}

/// Scan one file's source. `rel` is the path relative to the scan root
/// (used for classification and reporting). Returns surviving findings
/// and the number of allow-suppressed ones.
pub fn scan_source(rel: &str, src: &str) -> (Vec<Finding>, usize) {
    let stripped = strip(src);
    let toks = tokenize(&stripped.code);
    let ctx = build_context(&toks);
    let scan = FileScan { rel, class: classify(rel), toks, ctx, stripped };

    let mut findings = Vec::new();
    let allows = parse_allows(rel, &scan.stripped.comments, &mut findings);
    let mut raw = Vec::new();
    scan.d001(&mut raw);
    scan.d002(&mut raw);
    scan.d003(&mut raw);
    scan.u001(&mut raw);

    let mut suppressed = 0usize;
    for f in raw {
        // Re-derive the statement line for the allow search: findings
        // carry 1-based lines.
        let line0 = f.line - 1;
        let stmt_line = scan
            .toks
            .iter()
            .position(|t| t.line == line0)
            .map(|i| scan.toks[scan.ctx.stmt_start[i]].line)
            .unwrap_or(line0);
        if allows.covers(&scan.stripped.code, f.rule, line0, stmt_line) {
            suppressed += 1;
        } else {
            findings.push(f);
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    (findings, suppressed)
}

/// Recursively scan `root` (a directory of `.rs` files, or a single
/// file). Files are visited in sorted path order, so output is stable.
pub fn scan_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut report = Report::default();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .map(|p| {
                p.components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect::<Vec<_>>()
                    .join("/")
            })
            .unwrap_or_else(|_| path.to_string_lossy().into_owned());
        let rel = if rel.is_empty() { path.to_string_lossy().into_owned() } else { rel };
        let src = fs::read_to_string(&path)?;
        let (findings, suppressed) = scan_source(&rel, &src);
        report.findings.extend(findings);
        report.allows_used += suppressed;
        report.files += 1;
    }
    Ok(report)
}

fn collect_rs_files(path: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    let meta = fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    for entry in fs::read_dir(path)? {
        collect_rs_files(&entry?.path(), out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rel: &str, src: &str) -> Vec<(usize, &'static str)> {
        scan_source(rel, src).0.into_iter().map(|f| (f.line, f.rule)).collect()
    }

    // ---- classification -------------------------------------------------

    #[test]
    fn classification_defaults_unknown_modules_to_round_path() {
        assert_eq!(classify("chain/yuma.rs"), ModuleClass::RoundPath);
        assert_eq!(classify("lib.rs"), ModuleClass::RoundPath);
        assert_eq!(classify("shiny_new_subsystem/mod.rs"), ModuleClass::RoundPath);
        assert_eq!(classify("bench/suite.rs"), ModuleClass::Edge);
        assert_eq!(classify("main.rs"), ModuleClass::Edge);
        assert_eq!(classify("prop/scenario.rs"), ModuleClass::Edge);
    }

    // ---- D001 -----------------------------------------------------------

    #[test]
    fn d001_fires_on_hashmap_iteration() {
        let src = "fn f() {\n    let m: HashMap<u32, f64> = HashMap::new();\n    for (k, v) in m.iter() { use_it(k, v); }\n}\n";
        assert_eq!(findings("chain/mod.rs", src), vec![(3, "D001")]);
    }

    #[test]
    fn d001_fires_on_bare_for_over_hashset() {
        let src = "fn f() {\n    let mut seen = HashSet::new();\n    for x in seen { go(x); }\n}\n";
        assert_eq!(findings("coordinator/round.rs", src), vec![(3, "D001")]);
    }

    #[test]
    fn d001_ignores_keyed_lookup_and_btreemap() {
        let src = "fn f() {\n    let m: HashMap<u32, f64> = HashMap::new();\n    let v = m.get(&3);\n    m.insert(1, 2.0);\n    let b: BTreeMap<u32, f64> = BTreeMap::new();\n    for (k, v) in b.iter() { use_it(k, v); }\n}\n";
        assert!(findings("chain/mod.rs", src).is_empty());
    }

    #[test]
    fn d001_silent_in_edge_modules_and_tests() {
        let src = "fn f() {\n    let m = HashMap::new();\n    for x in m.keys() { go(x); }\n}\n";
        assert!(findings("bench/suite.rs", src).is_empty());
        let gated = format!("#[cfg(test)]\nmod tests {{\n{src}\n}}\n");
        assert!(findings("chain/mod.rs", &gated).is_empty());
    }

    // ---- D002 -----------------------------------------------------------

    #[test]
    fn d002_fires_on_clock_env_entropy() {
        let src = "fn f() {\n    let t = Instant::now();\n    let v = std::env::var(\"X\");\n    let r = thread_rng();\n}\n";
        assert_eq!(
            findings("runtime/mod.rs", src),
            vec![(2, "D002"), (3, "D002"), (4, "D002")]
        );
    }

    #[test]
    fn d002_blesses_effective_threads_and_edge() {
        let src = "impl RunConfig {\n    pub fn effective_threads(&self) -> usize {\n        if let Ok(v) = std::env::var(\"GAUNTLET_THREADS\") { return 1; }\n        4\n    }\n}\n";
        assert!(findings("coordinator/run.rs", src).is_empty());
        let edge = "fn f() { let t = Instant::now(); }\n";
        assert!(findings("bench/mod.rs", edge).is_empty());
    }

    #[test]
    fn d002_not_fooled_by_env_macro_or_comments() {
        let src = "fn f() {\n    let d = env!(\"CARGO_MANIFEST_DIR\");\n    // Instant::now in a comment\n    let s = \"Instant::now\";\n}\n";
        assert!(findings("runtime/mod.rs", src).is_empty());
    }

    // ---- D003 -----------------------------------------------------------

    #[test]
    fn d003_fires_on_turbofish_and_annotated_sum() {
        let src = "fn f(xs: &[f64]) -> f64 {\n    let a = xs.iter().copied().sum::<f64>();\n    let b: f64 = xs.iter().copied().sum();\n    a + b\n}\n";
        assert_eq!(findings("openskill/mod.rs", src), vec![(2, "D003"), (3, "D003")]);
    }

    #[test]
    fn d003_fires_on_additive_float_fold() {
        let src = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().fold(0.0, |a, b| a + b)\n}\n";
        assert_eq!(findings("demo/mod.rs", src), vec![(2, "D003")]);
    }

    #[test]
    fn d003_exempts_int_sums_and_minmax_folds() {
        let src = "fn f(xs: &[f64], ns: &[usize]) -> f64 {\n    let n: usize = ns.iter().sum();\n    let hi = xs.iter().copied().fold(0.0_f64, f64::max);\n    let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);\n    hi + lo + n as f64\n}\n";
        assert!(findings("chain/yuma.rs", src).is_empty());
    }

    #[test]
    fn d003_allow_with_reason_suppresses() {
        let src = "fn f(xs: &[f64]) -> f64 {\n    // detlint: allow(D003, in-order slice sum; order fixed by construction)\n    xs.iter().sum::<f64>()\n}\n";
        let (found, suppressed) = scan_source("chain/yuma.rs", src);
        assert!(found.is_empty(), "{found:?}");
        assert_eq!(suppressed, 1);
    }

    #[test]
    fn d003_allow_without_reason_is_reported() {
        let src = "fn f(xs: &[f64]) -> f64 {\n    // detlint: allow(D003)\n    xs.iter().sum::<f64>()\n}\n";
        let rules: Vec<&str> = scan_source("chain/yuma.rs", src)
            .0
            .into_iter()
            .map(|f| f.rule)
            .collect();
        assert!(rules.contains(&"ALLOW"), "{rules:?}");
        assert!(rules.contains(&"D003"), "bare allow must not suppress: {rules:?}");
    }

    // ---- U001 -----------------------------------------------------------

    #[test]
    fn u001_fires_without_safety_comment() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(findings("storage/mod.rs", src), vec![(2, "U001")]);
    }

    #[test]
    fn u001_accepts_safety_comment_and_doc_section() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n\n/// Does things.\n///\n/// # Safety\n///\n/// `p` must be valid.\npub unsafe fn g(p: *const u8) -> u8 {\n    // SAFETY: contract forwarded to the caller.\n    unsafe { *p }\n}\n";
        assert!(findings("util/mod.rs", src).is_empty());
    }

    #[test]
    fn u001_safety_comment_above_multiline_statement() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: p valid.\n    let x =\n        unsafe { *p };\n    x\n}\n";
        assert!(findings("util/mod.rs", src).is_empty());
    }

    #[test]
    fn u001_applies_in_edge_modules_too() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(findings("bench/mod.rs", src), vec![(2, "U001")]);
    }

    // ---- scanner robustness --------------------------------------------

    #[test]
    fn scanner_survives_strings_chars_lifetimes_raw_strings() {
        let src = "fn f<'env>(x: &'env str) -> char {\n    let a = \"Instant::now() \\\" escaped\";\n    let b = r#\"env::var(\"inside raw\")\"#;\n    let c = '\"';\n    let d = '\\n';\n    let e = b\"thread_rng\";\n    c\n}\n";
        assert!(findings("runtime/mod.rs", src).is_empty());
    }

    #[test]
    fn cfg_not_loom_is_not_skipped() {
        // `#[cfg(not(loom))]` items are real round-path code.
        let src = "#[cfg(not(loom))]\nfn f() {\n    let t = Instant::now();\n}\n";
        assert_eq!(findings("runtime/pool.rs", src), vec![(3, "D002")]);
    }
}
