//! Must-not-fire fixture: `bench` is an edge module — measurement code
//! legitimately reads clocks, environment, and hash-iterates scratch maps.
//! Not compiled; consumed by `tests/corpus.rs`.

use std::collections::HashMap;
use std::time::Instant;

pub fn measure(reps: usize) -> f64 {
    let t0 = Instant::now();
    let mut counts: HashMap<&'static str, u64> = HashMap::new();
    for _ in 0..reps {
        *counts.entry("iter").or_insert(0) += 1;
    }
    for (_, n) in counts.iter() {
        let _ = n;
    }
    let budget = std::env::var("GAUNTLET_BENCH_BUDGET").ok();
    let _ = budget;
    t0.elapsed().as_secs_f64()
}
