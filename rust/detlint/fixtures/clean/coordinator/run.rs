//! Must-not-fire fixture: the single blessed env-read site. D002 exempts
//! the body of `fn effective_threads` by name — thread count is resolved
//! once at assembly, and the fingerprint tests prove the result is
//! thread-count invariant anyway.
//! Not compiled; consumed by `tests/corpus.rs`.

pub struct RunConfig {
    pub threads: usize,
}

impl RunConfig {
    pub fn effective_threads(&self) -> usize {
        if let Ok(v) = std::env::var("GAUNTLET_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        self.threads.max(1)
    }
}
