//! Must-not-fire fixture: keyed hash lookups, ordered iteration, integer
//! sums, and min/max folds are all fine on the round path.
//! Not compiled; consumed by `tests/corpus.rs`.

use std::collections::{BTreeMap, HashMap};

pub struct Registry {
    by_uid: HashMap<u16, u64>,
    ordered: BTreeMap<u16, u64>,
}

impl Registry {
    pub fn lookup(&self, uid: u16) -> Option<u64> {
        // Keyed lookup is order-free: fine.
        self.by_uid.get(&uid).copied()
    }

    pub fn install(&mut self, uid: u16, stake: u64) {
        self.by_uid.insert(uid, stake);
        self.ordered.insert(uid, stake);
        let _ = self.by_uid.contains_key(&uid);
    }

    pub fn walk(&self) -> u64 {
        // BTreeMap iteration is key-ordered: fine.
        let mut acc = 0u64;
        for (_, stake) in self.ordered.iter() {
            acc += stake;
        }
        acc
    }
}

pub fn int_sum(ns: &[usize]) -> usize {
    // Integer sums are exact in any order: fine.
    ns.iter().sum()
}

pub fn extremes(xs: &[f64]) -> (f64, f64) {
    // Pure min/max folds are order-insensitive: fine.
    let hi = xs.iter().copied().fold(0.0_f64, f64::max);
    let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
    (hi, lo)
}
