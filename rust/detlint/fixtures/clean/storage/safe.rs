//! Must-not-fire fixture: every unsafe site carries a justification —
//! a `// SAFETY:` comment on/above the statement, or a `# Safety` doc
//! section on an unsafe fn. Not compiled; consumed by `tests/corpus.rs`.

pub fn read_checked(bytes: &[u8]) -> u32 {
    let mut buf = [0u8; 4];
    buf.copy_from_slice(&bytes[..4]);
    // SAFETY: `buf` is a fully-initialized 4-byte array; transmuting to
    // u32 reads exactly those 4 bytes with no padding.
    unsafe { std::mem::transmute::<[u8; 4], u32>(buf) }
}

/// Reads a raw pointer.
///
/// # Safety
///
/// `p` must be non-null, aligned, and valid for reads of one byte.
pub unsafe fn read_raw(p: *const u8) -> u8 {
    // SAFETY: contract forwarded verbatim to the caller.
    unsafe { *p }
}

pub fn multiline_statement(p: *const u8) -> u8 {
    // SAFETY: `p` comes from a live Box in this module, so it is valid.
    let value =
        unsafe { read_raw(p) };
    value
}
