//! Must-not-fire fixture: blessed reductions, a justified allow, and
//! cfg(test)-gated code (tests assert on round behaviour, they don't
//! produce it). Not compiled; consumed by `tests/corpus.rs`.

pub fn det_sum<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    // Open-coded in-order accumulation IS the blessed reduction: the
    // association order is pinned by construction.
    let mut acc = 0.0;
    for x in xs {
        acc += x;
    }
    acc
}

pub fn norm(xs: &[f64]) -> f64 {
    det_sum(xs.iter().map(|x| x * x)).sqrt()
}

pub fn checksum(xs: &[f64]) -> f64 {
    // detlint: allow(D003, slice iteration is strictly in-order, bit-identical to det_sum)
    xs.iter().sum::<f64>()
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use std::time::Instant;

    #[test]
    fn test_code_is_exempt() {
        // Inside cfg(test): clocks, hash iteration, and bare sums are all
        // fine — tests observe the round path, they don't feed it.
        let t0 = Instant::now();
        let mut m = HashMap::new();
        m.insert(1u16, 2.0f64);
        let mut total = 0.0;
        for (_, v) in m.iter() {
            total += v;
        }
        let s: f64 = [1.0f64, 2.0].iter().copied().sum();
        assert!(total + s > 0.0);
        let _ = t0.elapsed();
    }
}
