//! Must-fire fixture: U001 (unjustified unsafe) and ALLOW (bare allow with
//! no reason). Not compiled; consumed by `tests/corpus.rs`.

pub fn read_bad(p: *const u8) -> u8 {
    // FIRE(U001): no justification comment anywhere near this block.
    unsafe { *p }
}

pub fn sum_bad(xs: &[f64]) -> f64 {
    // detlint: allow(D003)
    // FIRE(ALLOW): the directive above has no reason, so it is reported
    // AND the D003 underneath still fires.
    xs.iter().sum::<f64>()
}
