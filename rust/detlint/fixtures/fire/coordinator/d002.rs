//! Must-fire fixture: D002 — clock/entropy/env reads in a round-path module.
//! Not compiled; consumed by `tests/corpus.rs`.

use std::time::{Instant, SystemTime};

pub fn timed_round() -> u64 {
    // FIRE: wall-clock read on the round path.
    let t0 = Instant::now();
    let _wall = SystemTime::now(); // FIRE
    t0.elapsed().as_nanos() as u64
}

pub fn env_round() -> usize {
    // FIRE: environment read outside the blessed effective_threads site.
    match std::env::var("GAUNTLET_SECRET_KNOB") {
        Ok(v) => v.len(),
        Err(_) => 0,
    }
}

pub fn entropy_round() -> u64 {
    // FIRE: OS entropy on the round path.
    let mut rng = thread_rng();
    rng.next_u64()
}
