//! Must-fire fixture: D001 — hash-ordered iteration in a round-path module.
//! Not compiled; consumed by `tests/corpus.rs`.

use std::collections::{HashMap, HashSet};

pub struct Book {
    scores: HashMap<u16, f64>,
}

impl Book {
    pub fn total_bad(&self) -> f64 {
        let mut acc = 0.0;
        // FIRE: iteration order depends on the hasher seed.
        for (_, v) in self.scores.iter() {
            acc += v;
        }
        acc
    }

    pub fn drain_bad(&mut self) {
        // FIRE: drain() yields in hash order.
        for (_, _) in self.scores.drain() {}
    }
}

pub fn visit_bad() {
    let mut seen = HashSet::new();
    seen.insert(3u16);
    // FIRE: bare `for .. in set` iterates in hash order.
    for uid in seen {
        let _ = uid;
    }
}
