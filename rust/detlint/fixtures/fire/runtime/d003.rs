//! Must-fire fixture: D003 — bare float reductions in a round-path module.
//! Not compiled; consumed by `tests/corpus.rs`.

pub fn norm_bad(xs: &[f32]) -> f64 {
    // FIRE: turbofish float sum; association order is the iterator's business.
    xs.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
}

pub fn total_bad(xs: &[f64]) -> f64 {
    // FIRE: bare .sum() on a statement that is visibly float-typed.
    let total: f64 = xs.iter().copied().sum();
    total
}

pub fn fold_bad(xs: &[f64]) -> f64 {
    // FIRE: additive fold seeded with a float literal.
    xs.iter().fold(0.0_f64, |acc, x| acc + x)
}
