//! Corpus tests: every rule has a must-fire fixture under `fixtures/fire`
//! and a must-not-fire fixture under `fixtures/clean`, and the real
//! `rust/src` tree scans clean — the same assertion CI's static-analysis
//! job makes via the binary.

use std::path::{Path, PathBuf};

fn fixtures(which: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(which)
}

fn rules_fired(report: &detlint::Report) -> Vec<&'static str> {
    let mut rules: Vec<&'static str> = report.findings.iter().map(|f| f.rule).collect();
    rules.sort();
    rules.dedup();
    rules
}

#[test]
fn fire_corpus_raises_every_rule() {
    let report = detlint::scan_tree(&fixtures("fire")).expect("scan fire corpus");
    let rules = rules_fired(&report);
    for want in ["ALLOW", "D001", "D002", "D003", "U001"] {
        assert!(
            rules.contains(&want),
            "fire corpus must raise {want}; raised {rules:?}:\n{}",
            render(&report)
        );
    }
    // The exact per-file shape is pinned so a scanner regression that
    // half-fires (or double-fires) is caught, not just total silence.
    let count = |file: &str, rule: &str| {
        report.findings.iter().filter(|f| f.file == file && f.rule == rule).count()
    };
    assert_eq!(count("chain/d001.rs", "D001"), 3, "{}", render(&report));
    assert_eq!(count("coordinator/d002.rs", "D002"), 4, "{}", render(&report));
    assert_eq!(count("runtime/d003.rs", "D003"), 3, "{}", render(&report));
    assert_eq!(count("storage/u001.rs", "U001"), 1, "{}", render(&report));
    assert_eq!(count("storage/u001.rs", "ALLOW"), 1, "{}", render(&report));
    // A bare allow must not suppress the finding underneath it.
    assert_eq!(count("storage/u001.rs", "D003"), 1, "{}", render(&report));
    assert_eq!(report.allows_used, 0);
}

#[test]
fn fire_findings_carry_line_anchors() {
    let report = detlint::scan_tree(&fixtures("fire")).expect("scan fire corpus");
    for f in &report.findings {
        assert!(f.line > 0, "finding without a line anchor: {f}");
        assert!(!f.message.is_empty(), "finding without a message: {f}");
    }
}

#[test]
fn clean_corpus_is_silent() {
    let report = detlint::scan_tree(&fixtures("clean")).expect("scan clean corpus");
    assert!(report.findings.is_empty(), "clean corpus must not fire:\n{}", render(&report));
    // Exactly one justified allow is exercised (runtime/kernels.rs).
    assert_eq!(report.allows_used, 1);
    assert_eq!(report.files, 5);
}

#[test]
fn gauntlet_round_path_scans_clean() {
    // The production assertion: the real tree has zero findings. This is
    // the in-process twin of CI's `cargo run -p detlint -- rust/src`.
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("src");
    let report = detlint::scan_tree(&src).expect("scan rust/src");
    assert!(report.files > 20, "expected the full gauntlet tree, got {} files", report.files);
    assert!(
        report.findings.is_empty(),
        "rust/src must scan clean; fix the site or add a reasoned allow:\n{}",
        render(&report)
    );
}

fn render(report: &detlint::Report) -> String {
    report.findings.iter().map(|f| format!("  {f}\n")).collect()
}
