# Build-time entry points. The Rust runtime loads AOT artifacts from
# rust/artifacts/<cfg>/ (override with GAUNTLET_ARTIFACT_DIR).

CONFIGS ?= nano,tiny

.PHONY: artifacts build test bench

artifacts:
	cd python && python -m compile.aot --configs $(CONFIGS) --out-dir ../rust/artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench hotpath
